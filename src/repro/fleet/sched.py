"""Array-native fleet control plane: scheduling as pure array ops.

The PR-1 scheduler was a per-request Python object loop (``Request``
dataclasses in deques, dict-keyed in-flight tickets). At fleet scale that
loop *is* the bottleneck: the JAX worker backend had to break its
``lax.scan`` at every scheduler macro-step for a host round-trip. This
module re-expresses every control-plane mechanism as pure, xp-parametric
(``xp`` is numpy or jax.numpy) functions over the struct-of-arrays
``SchedState`` so the same expressions serve two evaluation modes:

- the NumPy reference (``FleetScheduler`` in ``repro.fleet.scheduler``
  drives them tick-by-tick on the host), and
- the fused JAX path (``backend_jax.run_serve`` traces them *inside* the
  worker scan, so an entire serve trace — workers and scheduler — runs as
  one device launch with no host interleaving).

Mechanisms (array formulation of the PR-1 semantics):

- **Admission** — per-tick arrival *counts* per workload; arrivals beyond
  the global ``max_queue`` backlog are rejected (cumulative-count clip,
  workload-index order within a tick).
- **Queues** — one fixed-capacity ring buffer per workload holding
  (arrival time, retry count); retries/evictions re-enter at the *front*
  with their original arrival time (the paper prefers fresh samples, so a
  retried old request must not leapfrog shedding).
- **Shedding** — the longest stale prefix of each queue (age beyond
  ``shed_after_s``) is dropped via a cumulative-product prefix scan.
- **Routing** — dispatchable workers are ranked by a *budget score*
  (stable argsort, richest first); queues are served oldest-head-first.
  Reactive mode scores instantaneous usable energy; forecast mode scores
  the conditional expectation of usable energy over the next
  ``lookahead`` window under the worker's *compiled harvest forecaster*
  (``repro.core.forecast``: OU mean reversion, occlusion/burst regime
  models, or a learned AR(p) fit — selected per trace row) — a
  momentarily occluded worker on a rich trace outranks a momentarily
  charged worker on a scarce one.
- **Batching** — each assigned worker takes the largest batch of
  floor-knob requests its *planning* budget affords (forecast mode plans
  with expected inflow: harvest arriving while the batch executes funds
  in-flight work; shortfalls degrade to the worker's partial-emission
  path, not losses), then refines the per-request knob greedily. Queue
  consumption across workers is a cumulative-sum slice assignment — no
  per-request loop.
- **Eviction** — assignments that outlive
  ``grace + deadline_factor * est`` (``est`` from the per-worker MCU
  active power: heterogeneous fleets straggle heterogeneously) are
  revoked and requeued, the ``runtime.straggler`` deadline rule.
- **Quality-aware service** (``sched="quality"``) — queues are served in
  descending *marginal accuracy-per-joule* order (``SchedParams.QVALUE``,
  computed from the workload accuracy tables — measured oracle tables
  under ``repro.quality``) instead of oldest-head-first: when harvested
  energy cannot serve the whole backlog, the joules go to the requests
  that buy the most measured accuracy, and the starved low-value queues
  age out through the ordinary stale-prefix shed — value-ranked shedding
  without a second drop mechanism. Reactive and forecast modes are
  untouched (the rank key is the only difference, guarded by
  ``value_order``).
- **Quality ledger** — on every completion, ``collect`` gathers the
  request's *measured* quality from the precomputed
  ``(workload, sample, units)`` oracle table (``repro.quality.oracles``)
  and its table-priced spend in integer nanojoules, accumulating both
  into per-workload ``SchedState`` counters. Sample ids are assigned
  deterministically (the per-workload completion counter, cycling mod
  the oracle set size), so the fused scan needs no per-request records
  and both backends ledger identically.

Agreement contract: every *decision* (ranking, admission, batch sizes,
knob units, shed/evict counts) is integer arithmetic or elementwise IEEE
float ops evaluated identically by numpy and jax.numpy under
``enable_x64``, with stable sorts on both sides — the NumPy and fused-JAX
control planes agree exactly on emitted/skipped/power-cycle/completion
counts (pinned by tests/test_fleet_backends.py). Float *metric*
accumulators (latency sums, accuracy sums) may differ by reduction order
ulps and are compared with tolerances.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Sequence

import numpy as np

from repro.core.forecast import (FORECASTER_MODES, RowForecast,
                                 fit_row_forecast, usable_energy_rows,
                                 zero_row_forecast)
from repro.fleet.state import (SCHED_FIELDS, FleetParams, SchedParams,
                               SchedState, init_sched_state)

SS = collections.namedtuple("SS", SCHED_FIELDS)

Assignment = collections.namedtuple("Assignment",
                                    ["mask", "wl", "units", "batch"])

SCHED_MODES = ("reactive", "forecast", "quality")

_BIG = np.int64(1) << 40  # sentinel: floor unattainable -> never afford

_S_PROXY = 64  # synthetic oracle rows for workloads without a measured
# per-sample table: row s of the quantized table scores "correct" at u
# units iff s < round(accuracy[u] * _S_PROXY), so the ledgered mean
# reproduces the proxy accuracy curve to 1/64 without any randomness.


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------


def make_sched_params(p: FleetParams, workloads: Sequence, *,
                      max_queue: int = 4096, shed_after_s: float = 30.0,
                      max_batch: int = 4, max_retries: int = 2,
                      grace_s: float = 20.0, deadline_factor: float = 1.5,
                      sched: str = "reactive", lookahead_s: float = 5.0,
                      forecaster: str = "ou",
                      trace_families: Sequence[str] | None = None,
                      arp_order: int = 3,
                      forecaster_fit: str = "full",
                      lat_bins: int = 64, shards: int = 1,
                      rebalance_every: int = 0,
                      rebalance_max: int = 8,
                      persist: str = "none",
                      fram_write_j_per_byte: float = 18e-9,
                      fram_read_j_per_byte: float = 7e-9) -> SchedParams:
    """Compile the control-plane constants for one fleet.

    Stacks the workload cost/accuracy tables (joules / dimensionless),
    then fits + compiles the pluggable harvest forecaster
    (``repro.core.forecast``) per power-matrix row and gathers it per
    worker via ``p.trace_index``.

    Args:
        p: the fleet's static device configuration.
        workloads: ``FleetWorkload`` sequence (cost tables in J).
        max_queue: global admission bound, requests.
        shed_after_s / grace_s: staleness / straggler windows, seconds.
        max_batch: per-assignment batch cap, requests.
        max_retries: retry budget before a request counts as lost.
        deadline_factor: straggler deadline multiplier (dimensionless).
        sched: "reactive" (instantaneous budget), "forecast", or
            "quality" (reactive budget, queues served by marginal
            measured-accuracy-per-joule instead of age).
        lookahead_s: forecast window, seconds (rounded to >= 1 tick).
        forecaster: one of ``repro.core.forecast.FORECASTER_MODES``;
            "auto" picks a model per trace row (by ``trace_families``
            labels when given, else label-free classification).
        trace_families: optional per-power-row family names ("SOM", ...).
        arp_order: lag order p of the "arp" model (ticks).
        forecaster_fit: "full" fits the forecaster on the whole (R, T)
            bank (the historical offline behavior — it reads harvest
            samples the run has not produced yet); "causal" starts from
            the zero-inflow prior and leaves fitting to prefix-only
            refits (``FleetScheduler.refit_forecast``). Both compile to
            the same ``fc_order`` so refits never re-trace the scan.
        shards: hierarchical control planes (``--mesh-fleet K``): the
            worker axis splits into K contiguous blocks, each running an
            independent plane over ``n/K`` workers and a ``max_queue/K``
            admission slice. Must divide ``n`` evenly.
        rebalance_every: cross-shard work-stealing cadence in ticks
            (0 = off; when on, must be a positive multiple of the run's
            ``dispatch_every`` — checked at serve time).
        rebalance_max: per-workload cap on requests moved to the ring
            successor per rebalance event (the ppermute buffer width).
        persist: execution discipline ("none" | "ckpt" | "undolog") —
            must match the fleet's ``FleetParams.persist``. Exact
            disciplines pin the dispatch knob at NU and relax admission
            to the fixed+emit overhead (docs/persistence_plane.md).
        fram_write_j_per_byte / fram_read_j_per_byte: the NVM per-byte
            energies pricing the persistence plane (provenance record;
            the device-side joule tables live in ``FleetParams``).
    Returns:
        a frozen :class:`SchedParams`. Its ``quality`` provenance label
        is inferred: "measured" when any workload carries a per-sample
        oracle table (``qtab``), "proxy" otherwise.
    """
    if sched not in SCHED_MODES:
        raise ValueError(f"unknown sched mode {sched!r}; "
                         f"choose from {SCHED_MODES}")
    if forecaster not in FORECASTER_MODES:
        raise ValueError(f"unknown forecaster {forecaster!r}; "
                         f"choose from {FORECASTER_MODES}")
    shards = int(shards)
    if shards < 1 or p.n % shards:
        raise ValueError(
            f"--mesh-fleet {shards} does not divide the fleet: n={p.n} "
            f"workers must split into equal contiguous shards "
            f"(n % shards == {p.n % max(shards, 1)})")
    if rebalance_every < 0:
        raise ValueError(f"rebalance_every must be >= 0 ticks, got "
                         f"{rebalance_every}")
    if rebalance_max < 1:
        raise ValueError(f"rebalance_max must be >= 1, got "
                         f"{rebalance_max}")
    if forecaster_fit not in ("full", "causal"):
        raise ValueError(f"unknown forecaster_fit {forecaster_fit!r}; "
                         "choose from ('full', 'causal')")
    from repro.persist import PERSIST_MODES
    if persist not in PERSIST_MODES:
        raise ValueError(f"unknown persist mode {persist!r}; "
                         f"choose from {PERSIST_MODES}")
    if persist != getattr(p, "persist", "none"):
        raise ValueError(
            f"control-plane persist={persist!r} does not match the "
            f"fleet's FleetParams.persist={p.persist!r}")
    W = len(workloads)
    u_max = max(w.costs.n_units for w in workloads)
    CU = np.full((W, u_max + 2), np.inf)
    UCUM = np.full((W, u_max + 2), np.inf)
    ACC = np.zeros((W, u_max + 1))
    FIX = np.zeros(W)
    EMITC = np.zeros(W)
    NU = np.zeros(W, dtype=np.int64)
    FULL = np.zeros(W)
    P_REQ = np.zeros(W, dtype=np.int64)
    IS_SMART = np.zeros(W, dtype=bool)
    qtabs = [getattr(wk, "qtab", None) for wk in workloads]
    S_Q = np.array([_S_PROXY if q is None else q.shape[0] for q in qtabs],
                   dtype=np.int64)
    QTAB = np.zeros((W, int(S_Q.max()), u_max + 1), dtype=np.int64)
    QJ_NJ = np.zeros((W, u_max + 1), dtype=np.int64)
    QVALUE = np.zeros(W)
    QTARGET = np.zeros(W, dtype=np.int64)
    for w, wk in enumerate(workloads):
        nu = wk.costs.n_units
        NU[w] = nu
        CU[w, :nu + 1] = wk.costs.cumulative()
        UCUM[w, :nu + 1] = np.concatenate(
            [[0.0], np.cumsum(wk.costs.unit_costs)])
        FULL[w] = UCUM[w, nu]
        ACC[w, :nu + 1] = wk.accuracy
        FIX[w] = wk.costs.fixed_cost
        EMITC[w] = wk.costs.emit_cost
        if wk.floor > 0:
            IS_SMART[w] = True
            ok = np.nonzero(wk.accuracy >= wk.floor)[0]
            P_REQ[w] = int(ok[0]) if ok.size else _BIG
        # quality tables: measured per-sample oracle rows when the
        # workload carries them, the deterministic quantized proxy rows
        # otherwise; spend is priced from the cumulative cost table and
        # quantized to integer nanojoules (bit-exact ledger sums)
        if qtabs[w] is not None:
            QTAB[w, :S_Q[w], :nu + 1] = np.asarray(qtabs[w], np.int64)
        else:
            QTAB[w, :_S_PROXY, :nu + 1] = (
                np.arange(_S_PROXY)[:, None]
                < np.round(wk.accuracy[None, :] * _S_PROXY))
        QJ_NJ[w, :nu + 1] = np.round(CU[w, :nu + 1] * 1e9)
        u_eff = int(min(P_REQ[w] if IS_SMART[w] else nu, nu))
        QVALUE[w] = ((ACC[w, u_eff] - ACC[w, 0])
                     / max(CU[w, u_eff], 1e-300))
        QTARGET[w] = int(np.argmax(wk.accuracy))  # first knob at the max
    L = max(int(round(lookahead_s / p.dt)), 1)
    if sched == "forecast" and forecaster_fit == "causal":
        # honest start: nothing observed yet, forecast nothing. The
        # streaming loop (FleetScheduler.refit_forecast) swaps in
        # prefix-only fits at the same fixed fc_order.
        rf = zero_row_forecast(
            p.n, arp_order if forecaster == "arp" else 1)
    elif sched == "forecast":
        rf = fit_row_forecast(p.power, forecaster, L,
                              families=trace_families,
                              arp_order=arp_order).take(p.trace_index)
    else:
        # reactive planning never reads the forecast: skip the fit and
        # carry a trivial zero-forecast table (keeps params uniform and
        # the lag gather at order 1)
        z = np.zeros(p.n)
        rf = RowForecast(order=1, MU=z, W=z[:, None],
                         THRESH=np.full(p.n, np.inf), HI=z, LO=z,
                         model=np.zeros(p.n, dtype=np.int8))
    return SchedParams(
        n=p.n, W=W, Q=int(max_queue + p.n * max_batch), B=int(max_batch),
        max_queue=int(max_queue), max_retries=int(max_retries),
        shed_after_s=float(shed_after_s), grace_s=float(grace_s),
        deadline_factor=float(deadline_factor), dt=float(p.dt),
        CU=CU, UCUM=UCUM, FIX=FIX, EMITC=EMITC, NU=NU, FULL=FULL, ACC=ACC,
        P_REQ=P_REQ, IS_SMART=IS_SMART,
        forecast=(sched == "forecast"), lookahead_ticks=L,
        forecaster=str(forecaster), fc_order=int(rf.order),
        FC_MU=rf.MU, FC_W=rf.W, FC_THRESH=rf.THRESH, FC_HI=rf.HI,
        FC_LO=rf.LO, FC_MODEL=rf.model,
        ECAP=0.5 * p.C * (p.v_max * p.v_max - p.v_off * p.v_off),
        ACTIVE_P=np.asarray(p.active_power_w, dtype=np.float64),
        lat_bins=int(lat_bins),
        lat_max_s=2.0 * (float(shed_after_s) + float(grace_s)),
        quality=("measured" if any(q is not None for q in qtabs)
                 else "proxy"),
        value_order=(sched == "quality"),
        S_Q=S_Q, QTAB=QTAB, QJ_NJ=QJ_NJ, QVALUE=QVALUE,
        WL_RANK=np.argsort(-QVALUE, kind="stable").astype(np.int64),
        QTARGET=QTARGET, shards=shards,
        rebalance_every=int(rebalance_every),
        rebalance_max=int(rebalance_max),
        forecaster_fit=str(forecaster_fit),
        persist=str(persist),
        fram_write_j_per_byte=float(fram_write_j_per_byte),
        fram_read_j_per_byte=float(fram_read_j_per_byte))


def make_sched_state(sp: SchedParams) -> SchedState:
    """Empty :class:`SchedState` sized for ``sp`` (see
    ``state.init_sched_state``). Sharded params (``sp.shards > 1``) get
    the stacked per-shard form: every field carries a leading shard axis
    over per-shard shapes (``shard_sched_params``)."""
    if sp.shards > 1:
        base = init_sched_state(shard_sched_params(sp, 0))
        return SchedState(**{
            f: np.broadcast_to(
                getattr(base, f),
                (sp.shards,) + getattr(base, f).shape).copy()
            for f in SCHED_FIELDS})
    return init_sched_state(sp)


def power_lags(power, trace_index, i, T, order: int, phase=None, xp=np):
    """Gather the (N, P) power lag window the forecast planners read.

    Column j holds each worker's harvested power (watts) at trace tick
    ``i - j`` (column 0 is the current tick), indexed modulo the trace
    length ``T`` — traces are cyclic, matching the tick transition's own
    column arithmetic. ``phase`` is the optional (N,) per-worker tick
    offset. ``order`` (= ``SchedParams.fc_order``) is a static small int,
    so the gather unrolls identically under numpy and jax tracing.
    """
    cols = []
    for j in range(order):
        c = ((i - j) % T) if phase is None else (i + phase - j) % T
        cols.append(power[trace_index, c])
    return xp.stack(cols, axis=1)


# ---------------------------------------------------------------------------
# xp-generic primitives
# ---------------------------------------------------------------------------


def _argsort(a, xp):
    """Stable argsort on both array namespaces: ties break by index, so
    the NumPy and JAX control planes rank identically."""
    if xp is np:
        return np.argsort(a, kind="stable")
    return xp.argsort(a, stable=True)


def _scatter_set(a, idx, v, xp):
    if xp is np:
        out = a.copy()
        out[idx] = v
        return out
    return a.at[idx].set(v)


def _scatter_add(a, idx, v, xp):
    if xp is np:
        out = a.copy()
        np.add.at(out, idx, v)
        return out
    return a.at[idx].add(v)


# ---------------------------------------------------------------------------
# intake
# ---------------------------------------------------------------------------


def admit(sp: SchedParams, ss, counts, t, xp=np):
    """Admit this tick's arrivals up to the global backlog bound; reject
    the remainder.

    Args:
        counts: (W,) per-workload arrival counts this tick.
        t: arrival time stamped on admitted requests, seconds.
    Returns:
        the updated ``SchedState`` namedtuple view.

    All arrivals of one tick share the arrival time ``t``, so a push is a
    masked fill of the ring segment past each queue's tail."""
    if xp is np and int(np.sum(counts)) == 0:
        return ss  # pure no-op (identical to the masked write below)
    if xp is not np:
        # traced twin of the host fast path: skip the ring write on
        # zero-arrival ticks (the result is identical either way)
        from jax import lax
        return lax.cond(xp.sum(counts) > 0,
                        lambda s: _admit_impl(sp, s, counts, t, xp),
                        lambda s: s, ss)
    return _admit_impl(sp, ss, counts, t, xp)


def _admit_impl(sp: SchedParams, ss, counts, t, xp):
    counts = xp.asarray(counts).astype(xp.int64)
    backlog = xp.sum(ss.q_len)
    space = xp.maximum(sp.max_queue - backlog, 0)
    cum = xp.cumsum(counts)
    adm = xp.clip(space - (cum - counts), 0, counts)
    if xp is np:
        # reference-driver fast path: write exactly the admitted slots
        # (same values the masked whole-ring write below produces — the
        # admission *decision* above is shared, the store is sparse)
        q_t, q_r = ss.q_t.copy(), ss.q_r.copy()
        for w in range(sp.W):
            k = int(adm[w])
            if k:
                idx = (int(ss.q_head[w]) + int(ss.q_len[w])
                       + np.arange(k)) % sp.Q
                q_t[w, idx] = t
                q_r[w, idx] = 0
    else:
        slot = xp.arange(sp.Q)[None, :]
        pos = (slot - ss.q_head[:, None]) % sp.Q  # logical idx per slot
        new = ((pos >= ss.q_len[:, None])
               & (pos < (ss.q_len + adm)[:, None]))
        q_t = xp.where(new, t, ss.q_t)
        q_r = xp.where(new, 0, ss.q_r)
    return ss._replace(
        q_t=q_t, q_r=q_r,
        q_len=ss.q_len + adm,
        submitted=ss.submitted + xp.sum(counts),
        rejected=ss.rejected + xp.sum(counts - adm))


def shed(sp: SchedParams, ss, t, xp=np):
    """Drop the stale prefix of each queue (age ``t - arrival`` beyond
    ``shed_after_s`` seconds): a stale approximate answer is worth less
    than no answer. Prefix, not filter — ring contiguity is preserved
    and matches the PR-1 head-pop loop. Returns the updated state."""
    j = xp.arange(sp.Q)[None, :]
    phys = (ss.q_head[:, None] + j) % sp.Q
    log_t = xp.take_along_axis(ss.q_t, phys, axis=1)
    stale = (j < ss.q_len[:, None]) & (t - log_t > sp.shed_after_s)
    n_shed = xp.sum(xp.cumprod(stale.astype(xp.int64), axis=1), axis=1)
    return ss._replace(
        q_head=(ss.q_head + n_shed) % sp.Q,
        q_len=ss.q_len - n_shed,
        shed=ss.shed + xp.sum(n_shed))


# ---------------------------------------------------------------------------
# routing / batching
# ---------------------------------------------------------------------------


def plan_budget(sp: SchedParams, budget_now, pw_lags, eff, xp=np):
    """The budget (joules) routing and batching plan against.

    Reactive: the instantaneous usable energy. Forecast: usable energy
    plus the expected harvest over the lookahead window under each
    worker's compiled forecaster, capped at the buffer's storable
    ceiling (``repro.core.forecast.usable_energy_rows`` — one expression
    for all four models).

    Args:
        budget_now: (N,) instantaneous usable energy, J.
        pw_lags: (N, fc_order) power lag window from :func:`power_lags`,
            watts (ignored in reactive mode).
        eff: booster conversion efficiency (dimensionless).
    Returns:
        (N,) planning budget, J.
    """
    if not sp.forecast:
        return budget_now
    rf = RowForecast(order=sp.fc_order, MU=sp.FC_MU, W=sp.FC_W,
                     THRESH=sp.FC_THRESH, HI=sp.FC_HI, LO=sp.FC_LO,
                     model=sp.FC_MODEL)
    return usable_energy_rows(
        rf, budget_now, pw_lags, sp.lookahead_ticks * sp.dt,
        e_cap=sp.ECAP, booster_eff=eff, xp=xp)


def dispatch(sp: SchedParams, ss, dispatchable, budget_now, budget_plan,
             t, xp=np):
    """Route queued requests to capable workers.

    Args:
        dispatchable: (N,) bool — on, idle, nothing pending.
        budget_now: (N,) instantaneous usable energy, J.
        budget_plan: (N,) planning budget from :func:`plan_budget`, J.
        t: assignment time, seconds.
    Returns:
        ``(ss, a)`` — the updated state and an :class:`Assignment` of
        per-worker arrays (mask, workload id, per-request knob units,
        batch size) the caller writes into the device state
        (``p_pending`` and friends).

    Workers are ranked richest-first by ``budget_plan`` (stable sort);
    queues are served oldest-head-first (or, under ``sp.value_order``,
    best marginal-accuracy-per-joule first). Per worker: SMART admission at
    the workload floor on the *instantaneous* budget (never start work
    whose fixed cost is unfunded today), batch size and greedy knob
    refinement on the *planning* budget (forecast inflow funds in-flight
    units). Queue consumption is a cumulative-sum slice per workload."""
    i64 = xp.int64
    score = xp.where(dispatchable, budget_plan, -xp.inf)
    order = _argsort(-score, xp)  # rank -> worker id, richest first
    elig = xp.take(dispatchable, order)
    bn = xp.take(budget_now, order)
    bp = xp.take(budget_plan, order)
    if sp.value_order:
        # sched="quality": serve queues richest-in-accuracy-per-joule
        # first (a params constant, so the order is static under tracing)
        wl_order = xp.asarray(sp.WL_RANK)
    else:
        head_t = xp.where(
            ss.q_len > 0,
            xp.take_along_axis(ss.q_t, ss.q_head[:, None], axis=1)[:, 0],
            xp.inf)
        wl_order = _argsort(head_t, xp)
    q_head, q_len = ss.q_head, ss.q_len
    taken = xp.zeros(sp.n, dtype=bool)
    a_wl = xp.zeros(sp.n, dtype=i64)
    a_units = xp.zeros(sp.n, dtype=i64)
    a_batch = xp.zeros(sp.n, dtype=i64)
    g_arr = xp.zeros((sp.n, sp.B))
    g_retry = xp.zeros((sp.n, sp.B), dtype=i64)
    jB = xp.arange(sp.B)[None, :]
    for k in range(sp.W):  # static: one pass per workload queue
        wl = wl_order[k]
        cu = xp.take(xp.asarray(sp.CU), wl, axis=0)
        ucum = xp.take(xp.asarray(sp.UCUM), wl, axis=0)
        nu = xp.take(xp.asarray(sp.NU), wl)
        overhead = (xp.take(xp.asarray(sp.FIX), wl)
                    + xp.take(xp.asarray(sp.EMITC), wl))
        qrem = xp.take(q_len, wl)
        head = xp.take(q_head, wl)
        # admission: largest knob the instantaneous budget affords (-1:
        # even fixed+emit does not fit), SMART floor for floored workloads
        k_aff = xp.searchsorted(cu, bn, side="right").astype(i64) - 1
        if sp.persist != "none":
            # exact disciplines (docs/persistence_plane.md): the knob is
            # pinned at NU — every unit runs — and admission only needs
            # the fixed+emit overhead funded now; the persisted request
            # survives power failure and spans recharge cycles
            p_req = xp.zeros(sp.n, dtype=i64) + nu
            afford = k_aff >= 0
        else:
            p_req = xp.where(xp.take(xp.asarray(sp.IS_SMART), wl),
                             xp.take(xp.asarray(sp.P_REQ), wl),
                             xp.maximum(k_aff, 0))
            afford = (k_aff >= p_req) & (k_aff >= 0)
        # batch sizing on the *planning* budget (forecast inflow lets more
        # floor-knob requests ride one power cycle, amortizing fixed+emit
        # overhead); greedy knob refinement on the *instantaneous* budget
        # (spend expected inflow on throughput, never on slower service).
        # Quality mode sizes batches at the max-measured-accuracy knob
        # instead of the floor knob: fewer requests ride one power cycle,
        # each affording the knob where the oracle says accuracy peaks —
        # under scarcity the target degrades back to the floor (b_want
        # clips to >= 1 and refinement still bounds at p_req).
        spend_plan = bp - overhead
        spend_now = bn - overhead
        cpr = xp.take(ucum, xp.clip(p_req, 0, ucum.shape[0] - 1))
        if sp.value_order and sp.persist == "none":
            # quality mode also CAPS refinement at the target knob:
            # measured tables are non-monotonic, so units past the peak
            # cost strictly more joules for no more (often less)
            # measured accuracy
            u_cap = xp.maximum(xp.take(xp.asarray(sp.QTARGET), wl), p_req)
            cpq = xp.take(ucum, xp.clip(u_cap, 0, ucum.shape[0] - 1))
            cpb = xp.maximum(cpq, cpr)  # never below the admission knob
        else:
            u_cap = nu
            cpb = cpr
        b_want = xp.where(
            cpb > 0,
            xp.floor_divide(spend_plan, xp.maximum(cpb, 1e-300)), sp.B)
        b_want = xp.clip(b_want, 1, sp.B).astype(i64)
        u_want = xp.clip(
            xp.searchsorted(ucum, spend_now / xp.maximum(b_want, 1),
                            side="right").astype(i64) - 1,
            p_req, u_cap)
        ok = elig & ~taken & afford & (u_want > 0)
        b = xp.where(ok, b_want, 0)
        c = xp.cumsum(b)
        start = c - b
        actual = xp.clip(qrem - start, 0, b)
        got = ok & (actual > 0)
        u = xp.clip(
            xp.searchsorted(ucum, spend_now / xp.maximum(actual, 1),
                            side="right").astype(i64) - 1,
            p_req, u_cap)
        # consume the queue front: gather each worker's request slice
        phys = (head + start[:, None] + jB) % sp.Q
        row_t = xp.take(ss.q_t, wl, axis=0)
        row_r = xp.take(ss.q_r, wl, axis=0)
        take_mask = got[:, None] & (jB < actual[:, None])
        g_arr = xp.where(take_mask, xp.take(row_t, phys), g_arr)
        g_retry = xp.where(take_mask, xp.take(row_r, phys), g_retry)
        consumed = xp.sum(actual)
        onehot = xp.arange(sp.W) == wl
        q_head = xp.where(onehot, (q_head + consumed) % sp.Q, q_head)
        q_len = xp.where(onehot, q_len - consumed, q_len)
        taken = taken | got
        a_wl = xp.where(got, wl, a_wl)
        a_units = xp.where(got, u, a_units)
        a_batch = xp.where(got, actual, a_batch)
    # rank space -> worker space (order is a permutation)
    z = lambda dt=i64: xp.zeros(sp.n, dtype=dt)  # noqa: E731
    batch_w = _scatter_set(z(), order, a_batch, xp)
    mask_w = batch_w > 0
    wl_w = _scatter_set(z(), order, a_wl, xp)
    units_w = _scatter_set(z(), order, a_units, xp)
    arr_w = _scatter_set(xp.zeros((sp.n, sp.B)), order, g_arr, xp)
    retry_w = _scatter_set(xp.zeros((sp.n, sp.B), dtype=i64), order,
                           g_retry, xp)
    ss = ss._replace(
        q_head=q_head, q_len=q_len,
        f_n=xp.where(mask_w, batch_w, ss.f_n),
        f_wl=xp.where(mask_w, wl_w, ss.f_wl),
        f_units=xp.where(mask_w, units_w, ss.f_units),
        f_t0=xp.where(mask_w, t, ss.f_t0),
        f_arr=xp.where(mask_w[:, None], arr_w, ss.f_arr),
        f_retry=xp.where(mask_w[:, None], retry_w, ss.f_retry),
        batch_hist=ss.batch_hist + xp.sum(
            (batch_w[:, None] == xp.arange(sp.B + 1)[None, :])
            & mask_w[:, None], axis=0))
    return ss, Assignment(mask_w, wl_w, units_w, batch_w)


# ---------------------------------------------------------------------------
# completion / loss / eviction
# ---------------------------------------------------------------------------


def _requeue(sp: SchedParams, ss, slots, xp=np):
    """Grant retries to the in-flight request ``slots`` ((N, B) mask):
    retry budget exceeded -> lost; otherwise re-enter the owning workload
    queue at the *front*, preserving (worker, slot) order, with original
    arrival times (so shedding still sees their true age)."""
    if xp is np:
        if not slots.any():
            return ss  # pure no-op fast path for the reference driver
        return _requeue_impl(sp, ss, slots, xp)
    # traced twin: retries are rare relative to ticks — skip the ring
    # scatter entirely on clean ticks (identical result either way)
    from jax import lax
    return lax.cond(xp.any(slots),
                    lambda s: _requeue_impl(sp, s, slots, xp),
                    lambda s: s, ss)


def _requeue_impl(sp: SchedParams, ss, slots, xp):
    newr = ss.f_retry + 1
    give_up = slots & (newr > sp.max_retries)
    keep = slots & ~give_up
    q_t, q_r, q_head, q_len = ss.q_t, ss.q_r, ss.q_head, ss.q_len
    flat_keep = keep.reshape(-1)
    flat_t = ss.f_arr.reshape(-1)
    flat_r = newr.reshape(-1)
    flat_wl = xp.broadcast_to(ss.f_wl[:, None], keep.shape).reshape(-1)
    for w in range(sp.W):  # static: one front-insert pass per queue
        m = flat_keep & (flat_wl == w)
        kcount = xp.sum(m.astype(xp.int64))
        rank = xp.cumsum(m.astype(xp.int64)) - 1
        headnew = (q_head[w] - kcount) % sp.Q
        phys = xp.where(m, (headnew + rank) % sp.Q, sp.Q)  # Q: dump slot
        ext_t = xp.concatenate([q_t[w], xp.zeros(1)])
        ext_t = _scatter_set(ext_t, phys, xp.where(m, flat_t, 0.0), xp)
        ext_r = xp.concatenate([q_r[w], xp.zeros(1, dtype=xp.int64)])
        ext_r = _scatter_set(ext_r, phys, xp.where(m, flat_r, 0), xp)
        onehot = xp.arange(sp.W) == w
        q_t = xp.where(onehot[:, None], ext_t[None, :sp.Q], q_t)
        q_r = xp.where(onehot[:, None], ext_r[None, :sp.Q], q_r)
        q_head = xp.where(onehot, headnew, q_head)
        q_len = xp.where(onehot, q_len + kcount, q_len)
    return ss._replace(
        q_t=q_t, q_r=q_r, q_head=q_head, q_len=q_len,
        lost=ss.lost + xp.sum(give_up),
        requeued=ss.requeued + xp.sum(keep))


def collect(sp: SchedParams, ss, emit, lost, units_done, t, xp=np):
    """Retire this tick's device outcomes.

    Args:
        emit / lost: (N,) bool — workers that emitted / browned out.
        units_done: (N,) int64 units finished by emitting workers.
        t: completion time, seconds (drives the latency histogram).
    Returns:
        the updated state.

    An emitting worker completes ``units_done // u`` full requests of its
    batch (plus one partial: anytime semantics — a truncated result is
    still a result); the unfinished tail and all requests of browned-out
    workers go through the retry path."""
    if xp is np:
        if not (emit.any() or lost.any()):
            return ss
        return _collect_impl(sp, ss, emit, lost, units_done, t, xp)
    from jax import lax
    return lax.cond(
        xp.any(emit | lost),
        lambda s: _collect_impl(sp, s, emit, lost, units_done, t, xp),
        lambda s: s, ss)


def _collect_impl(sp: SchedParams, ss, emit, lost, units_done, t, xp):
    act = ss.f_n > 0
    em = emit & act
    lo = lost & act
    b = ss.f_n
    u = ss.f_units
    safe_u = xp.maximum(u, 1)
    full = xp.where(u > 0, units_done // safe_u, b)
    part = xp.where(u > 0, units_done % safe_u, 0)
    nfull = xp.minimum(full, b)
    haspart = (part > 0) & (full < b)
    jB = xp.arange(sp.B)[None, :]
    slotv = jB < b[:, None]
    compfull = em[:, None] & slotv & (jB < nfull[:, None])
    comppart = (em[:, None] & slotv & (jB == nfull[:, None])
                & haspart[:, None])
    comp = compfull | comppart
    unfinished = (em[:, None] & slotv & ~comp) | (lo[:, None] & slotv)
    units_slot = xp.where(compfull, u[:, None],
                          xp.where(comppart, part[:, None], 0))
    lat = t - ss.f_arr
    # fixed-bin latency histogram: integer scatter-adds agree exactly
    # across backends; percentiles come from the bins (metrics.py)
    binw = sp.lat_max_s / sp.lat_bins
    idx = xp.clip((lat / binw).astype(xp.int64), 0, sp.lat_bins - 1)
    idx = xp.where(comp, idx, sp.lat_bins)  # non-completions -> dump bin
    hist_ext = _scatter_add(xp.zeros(sp.lat_bins + 1, dtype=xp.int64),
                            idx.reshape(-1), 1, xp)
    # per-workload aggregates via the small one-hot W axis
    wl1h = ss.f_wl[:, None, None] == xp.arange(sp.W)[None, None, :]
    compc = (comp[:, :, None] & wl1h).astype(xp.int64)
    Uw = sp.ACC.shape[1]
    accv = xp.take(xp.asarray(sp.ACC).reshape(-1),
                   ss.f_wl[:, None] * Uw + xp.clip(units_slot, 0, Uw - 1))
    # quality ledger: each completion is scored against a deterministic
    # oracle sample — per workload, this tick's completions are numbered
    # in flat (worker, slot) order continuing the run-long completed_wl
    # counter, cycling mod the oracle set size — then measured
    # correctness (0/1) and the table-priced spend (integer nanojoules)
    # are gathered from the precomputed (workload, sample, units)
    # tables. Integer arithmetic only: both backends ledger bit-exactly.
    cc2 = compc.reshape(-1, sp.W)  # (N*B, W)
    sample = ((ss.completed_wl[None, :] + xp.cumsum(cc2, axis=0) - cc2)
              % xp.asarray(sp.S_Q)[None, :])
    Smax, Uq = sp.QTAB.shape[1], sp.QTAB.shape[2]
    uq = xp.clip(units_slot, 0, Uq - 1)
    qv = xp.take(xp.asarray(sp.QTAB).reshape(-1),
                 (xp.arange(sp.W)[None, :] * Smax + sample) * Uq
                 + uq.reshape(-1)[:, None])
    jnj = xp.take(xp.asarray(sp.QJ_NJ).reshape(-1),
                  ss.f_wl[:, None] * Uq + uq)
    ss = ss._replace(
        completed=ss.completed + xp.sum(comp),
        completed_wl=ss.completed_wl + xp.sum(compc, axis=(0, 1)),
        units_wl=ss.units_wl + xp.sum(units_slot[:, :, None] * compc,
                                      axis=(0, 1)),
        acc_wl=ss.acc_wl + xp.sum(xp.where(comp, accv, 0.0)[:, :, None]
                                  * compc, axis=(0, 1)),
        meas_wl=ss.meas_wl + xp.sum(qv * cc2, axis=0),
        joules_nj_wl=ss.joules_nj_wl + xp.sum(
            jnj.reshape(-1)[:, None] * cc2, axis=0),
        lat_sum=ss.lat_sum + xp.sum(xp.where(comp, lat, 0.0)),
        lat_hist=ss.lat_hist + hist_ext[:sp.lat_bins])
    ss = _requeue(sp, ss, unfinished, xp)
    return ss._replace(f_n=xp.where(em | lo, 0, ss.f_n))


def evict(sp: SchedParams, ss, t, xp=np):
    """Straggler pass: revoke assignments older than the service
    deadline ``grace_s + deadline_factor * est`` (seconds), where
    ``est`` prices the batch at the worker's own MCU active power (the
    device browned out before acquiring, or recharges too slowly).
    Returns ``(ss, ev)`` with ``ev`` the (N,) evicted mask; the caller
    clears the device's pending/in-flight flags for ``ev``."""
    act = ss.f_n > 0
    est = (xp.take(xp.asarray(sp.FIX), ss.f_wl)
           + xp.take(xp.asarray(sp.EMITC), ss.f_wl)
           + ss.f_n * xp.take(xp.asarray(sp.FULL), ss.f_wl)) / sp.ACTIVE_P
    ev = act & (t - ss.f_t0 > sp.grace_s + sp.deadline_factor * est)
    slots = ev[:, None] & (xp.arange(sp.B)[None, :] < ss.f_n[:, None])
    ss = ss._replace(evicted=ss.evicted + xp.sum(xp.where(ev, ss.f_n, 0)))
    ss = _requeue(sp, ss, slots, xp)
    return ss._replace(f_n=xp.where(ev, 0, ss.f_n)), ev


# ---------------------------------------------------------------------------
# sharded control plane (--mesh-fleet K): per-shard params/state + the
# cross-shard work-stealing rebalance, xp-generic so the fused JAX path
# (psum/ppermute collectives) and the NumPy host twin (axis-0 sums +
# np.roll) evaluate the same queue moves bit-exactly
# ---------------------------------------------------------------------------

# compiled forecast tables — the SchedParams arrays a causal refit
# replaces between chunks. The fused scan passes them as *runtime*
# inputs (not trace constants) so a refit never forces a re-trace;
# sched_params_compatible is the matching cache-invalidation rule.
FC_FIELDS = ("FC_MU", "FC_W", "FC_THRESH", "FC_HI", "FC_LO", "FC_MODEL")

# SchedParams fields indexed by worker (N,...) — the ones a per-shard
# view must slice to its contiguous worker block
PER_WORKER_FIELDS = FC_FIELDS + ("ECAP", "ACTIVE_P")


def sched_params_compatible(old: SchedParams | None,
                            new: SchedParams) -> bool:
    """True iff a scan compiled against ``old`` stays valid for ``new``.

    A causal refit rebinds only the ``FC_FIELDS`` tables (same shapes,
    same dtypes — ``fc_order`` is fixed per session), which the compiled
    serve functions take as runtime arguments; everything else in
    :class:`SchedParams` is baked into the trace, so any *other* change
    — a different table object, a different scalar — invalidates the
    compile cache exactly like the old identity check did."""
    if old is None:
        return False
    if old is new:
        return True
    for f in dataclasses.fields(SchedParams):
        a, b = getattr(old, f.name), getattr(new, f.name)
        if f.name in FC_FIELDS:
            a, b = np.asarray(a), np.asarray(b)
            if a.shape != b.shape or a.dtype != b.dtype:
                return False
        elif isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
            if a is not b:
                return False
        elif a != b:
            return False
    return True


def shard_sched_params(sp: SchedParams, shard: int | None = None,
                       per_worker: dict | None = None) -> SchedParams:
    """The single-shard view of a sharded :class:`SchedParams`.

    Shard ``s`` owns workers ``[s*n/K, (s+1)*n/K)``, an admission slice
    of ``max_queue // K`` requests, and a private ring sized
    ``max_queue//K + n_shard*B + rebalance_max`` — the last term is
    headroom so a rebalance push landing on a full queue (admission
    slice + every in-flight retry requeued at once) cannot overflow the
    ring. Pass ``shard`` on the host (NumPy slices of the per-worker
    fields) or ``per_worker`` inside a trace (the shard's tracer slices,
    e.g. under ``shard_map``/``vmap``)."""
    K = sp.shards
    ns = sp.n // K
    if per_worker is None:
        sl = slice(shard * ns, (shard + 1) * ns)
        per_worker = {f: getattr(sp, f)[sl] for f in PER_WORKER_FIELDS}
    return dataclasses.replace(
        sp, n=ns, shards=1,
        max_queue=sp.max_queue // K,
        Q=int(sp.max_queue // K + ns * sp.B + sp.rebalance_max),
        **per_worker)


def split_counts(counts, shards: int) -> np.ndarray:
    """Deterministic arrival split: shard ``s`` of ``K`` receives
    ``counts // K + (s < counts % K)`` requests — elementwise over any
    counts shape ((W,) per tick or (T, W) whole-run), so the shards'
    admissions sum exactly to the global stream. Host-side NumPy; both
    serve paths consume the same precomputed split."""
    counts = np.asarray(counts).astype(np.int64)
    s = np.arange(int(shards), dtype=np.int64).reshape(
        (int(shards),) + (1,) * counts.ndim)
    return counts // shards + (s < counts % shards)


# accounting fields summed over the shard axis by merged_sched_view
# (all order-free sums), split by their per-shard rank; the remaining
# fields (rings, in-flight slots) keep their stacked form
MERGED_SCALAR_FIELDS = ("submitted", "rejected", "shed", "lost",
                        "evicted", "requeued", "completed", "lat_sum",
                        "rebalanced")  # 0-d per shard
MERGED_ARRAY_FIELDS = ("completed_wl", "units_wl", "acc_wl", "lat_hist",
                       "batch_hist", "meas_wl", "joules_nj_wl")  # 1-d


def merged_sched_view(st) -> SS:
    """Aggregate a stacked (K, ...) sharded :class:`SchedState` into the
    global counter view ``metrics.sched_summary`` reads: every
    accounting field summed over the shard axis (all are order-free
    sums), structural fields (queues, in-flight slots) passed through
    stacked. Works on the unsharded state too (identity)."""
    vals = {}
    for f in SCHED_FIELDS:
        a = np.asarray(getattr(st, f))
        if f in MERGED_SCALAR_FIELDS and a.ndim > 0:
            vals[f] = a.sum()
        elif f in MERGED_ARRAY_FIELDS and a.ndim > 1:
            vals[f] = a.sum(axis=0)
        else:
            vals[f] = getattr(st, f)
    return SS(**vals)


def rebalance_capacity(budget_plan, xp=np):
    """One shard's energy capacity for the rebalance targets: the
    order-free int64 sum of its workers' planning budgets quantized
    elementwise to microjoules. µJ (not nJ) keeps the ``b_tot * cap``
    product well inside int64 at million-worker fleets."""
    return xp.sum(xp.round(budget_plan * 1e6).astype(xp.int64))


def rebalance_targets(backlog, cap, b_tot, c_tot, xp=np):
    """Forecast-weighted backlog targets: shard ``s`` should hold
    ``b_tot * cap_s // c_tot`` queued requests (energy-proportional
    share of the global backlog, integer floor). Returns
    ``(surplus, deficit)`` — requests above / below target. Scalars per
    shard under the collectives; (K,) arrays on the host twin."""
    target = (b_tot * cap) // xp.maximum(c_tot, 1)
    surplus = xp.maximum(backlog - target, 0)
    deficit = xp.maximum(target - backlog, 0)
    return surplus, deficit


def rebalance_moves(sp: SchedParams, q_len, give, xp=np):
    """Split one shard's total give-count into per-workload tail-pops:
    fixed workload order 0..W-1, each queue contributing at most
    ``min(q_len[w], rebalance_max)`` (vectorized greedy fill via the
    availability cumsum). ``give`` is an int64 scalar."""
    capw = xp.minimum(q_len, sp.rebalance_max)
    c = xp.cumsum(capw)
    return xp.clip(give - (c - capw), 0, capw).astype(xp.int64)


def queue_pop_tail(sp: SchedParams, ss, move, xp=np):
    """Pop ``move[w]`` requests from the TAIL of each workload ring
    (the youngest entries — stealing ships fresh work and leaves the
    oldest requests where shedding can still see their age) into fixed
    (W, rebalance_max) buffers, oldest-of-the-moved first. Pure value
    transfer: the (arrival time, retry count) payloads are copied
    bit-for-bit, no float arithmetic. Returns ``(ss, buf_t, buf_r)``."""
    R = sp.rebalance_max
    jR = xp.arange(R)[None, :]
    take = jR < move[:, None]
    pos = ss.q_len[:, None] - move[:, None] + jR  # logical, >= 0
    phys = (ss.q_head[:, None] + pos) % sp.Q
    buf_t = xp.where(take, xp.take_along_axis(ss.q_t, phys, axis=1), 0.0)
    buf_r = xp.where(take, xp.take_along_axis(ss.q_r, phys, axis=1), 0)
    return ss._replace(q_len=ss.q_len - move), buf_t, buf_r


def queue_push_tail(sp: SchedParams, ss, move, buf_t, buf_r, xp=np):
    """Push received rebalance buffers at each workload ring's tail,
    preserving buffer order (slot j of ``buf_*`` lands j-th). Unused
    buffer lanes scatter into a dump slot that is sliced off, mirroring
    ``_requeue_impl``'s ring-write idiom. Also counts the arrivals into
    ``ss.rebalanced``."""
    R = sp.rebalance_max
    jR = xp.arange(R)[None, :]
    put = jR < move[:, None]
    phys = xp.where(put, (ss.q_head[:, None] + ss.q_len[:, None] + jR)
                    % sp.Q, sp.Q)  # Q: per-row dump slot
    flat = (xp.arange(sp.W)[:, None] * (sp.Q + 1) + phys).reshape(-1)
    ext_t = xp.concatenate(
        [ss.q_t, xp.zeros((sp.W, 1))], axis=1).reshape(-1)
    ext_r = xp.concatenate(
        [ss.q_r, xp.zeros((sp.W, 1), dtype=xp.int64)], axis=1).reshape(-1)
    ext_t = _scatter_set(ext_t, flat,
                         xp.where(put, buf_t, 0.0).reshape(-1), xp)
    ext_r = _scatter_set(ext_r, flat,
                         xp.where(put, buf_r, 0).reshape(-1), xp)
    return ss._replace(
        q_t=ext_t.reshape(sp.W, sp.Q + 1)[:, :sp.Q],
        q_r=ext_r.reshape(sp.W, sp.Q + 1)[:, :sp.Q],
        q_len=ss.q_len + move,
        rebalanced=ss.rebalanced + xp.sum(move))


def rebalance_host(sps_list: Sequence[SchedParams], sss: list,
                   plans: Sequence) -> list:
    """The NumPy host twin of one cross-shard rebalance event.

    Mirrors the collective protocol exactly: ``psum`` totals become
    axis-0 sums, the ``ppermute`` ring shifts become ``np.roll`` —
    shard ``s`` learns its successor's deficit (roll -1), gives
    ``min(surplus_s, deficit_{s+1})`` requests popped from its queue
    tails, and receives its predecessor's send buffers (roll +1). Same
    helper functions as the traced path, so the queue contents agree
    bit-for-bit. Args are per-shard lists: params views, ``SS`` states,
    (n_shard,) planning budgets. Returns the updated states."""
    K = len(sss)
    backlog = np.array([int(np.sum(s.q_len)) for s in sss],
                       dtype=np.int64)
    cap = np.array([int(rebalance_capacity(pl, np)) for pl in plans],
                   dtype=np.int64)
    surplus, deficit = rebalance_targets(
        backlog, cap, backlog.sum(), cap.sum(), np)
    give = np.minimum(surplus, np.roll(deficit, -1))
    sent = []
    out = []
    for s in range(K):
        move = rebalance_moves(sps_list[s], sss[s].q_len, give[s], np)
        ss2, bt, br = queue_pop_tail(sps_list[s], sss[s], move, np)
        out.append(ss2)
        sent.append((move, bt, br))
    for s in range(K):
        move, bt, br = sent[(s - 1) % K]  # ppermute s -> s+1
        out[s] = queue_push_tail(sps_list[s], out[s], move, bt, br, np)
    return out
