"""Forecast-aware fleet dispatcher: a thin host frontend over the
array-native control plane (``repro.fleet.sched``).

The scheduler owns the global request stream and answers, every dispatch
tick, the fleet version of the paper's per-sample question: *which worker
should run this request, at which knob setting, so the result is emitted
within the worker's current power cycle?* Since PR 3 the answer is
computed by pure struct-of-arrays ops (queue ring-buffers, cumulative-sum
batching, stable-sort routing) instead of a per-request Python object
loop, so the same expressions run in two modes:

- ``backend="numpy"`` pools: :class:`FleetScheduler` drives the array ops
  tick-by-tick on the host — the bit-exact reference cadence;
- ``backend="jax"`` pools: :func:`run_fleet` hands the whole serve trace
  to ``backend_jax.run_serve`` — workers **and** scheduler fused into a
  single ``lax.scan`` device launch with no per-macro-step host
  round-trips.

Routing is *forecast-aware* (``sched="forecast"``): workers are ranked —
and batches sized — by the conditional expectation of usable energy over
the next ``lookahead_s`` window instead of instantaneous charge, under a
*pluggable* harvest forecaster (``repro.core.forecast``): the closed-form
OU mean reversion, the occlusion/burst regime models, a learned AR(p)
fit, or per-row automatic selection (``forecaster="auto"``, matched to
each row's trace family). ``sched="reactive"`` is the PR-1 behavior.
"""
from __future__ import annotations

import numpy as np

from repro.fleet import backend_numpy, sched as _sched
from repro.fleet.metrics import sched_summary
from repro.fleet.state import (sched_state_as_tuple, sched_state_from_tuple)
from repro.fleet.worker import EMIT, FleetWorkerPool
from repro.fleet.workloads import FleetWorkload
from repro.runtime.straggler import StragglerPolicy


class RequestStream:
    """Deterministic Poisson arrivals with a workload mix."""

    def __init__(self, rate_rps: float, mix: np.ndarray, n_steps: int,
                 dt: float, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.counts = rng.poisson(rate_rps * dt, size=n_steps)
        total = int(self.counts.sum())
        mix = np.asarray(mix, dtype=np.float64)
        self.wl = rng.choice(mix.shape[0], size=total, p=mix / mix.sum())
        self.offsets = np.concatenate([[0], np.cumsum(self.counts)])

    def arrivals(self, i: int) -> np.ndarray:
        """Workload indices of the requests arriving at step ``i``."""
        return self.wl[self.offsets[i]:self.offsets[i + 1]]

    def counts_matrix(self, n_workloads: int) -> np.ndarray:
        """(n_steps, W) per-tick arrival counts — the array-native form
        the fused serve scan consumes as its ``lax.scan`` input."""
        n_steps = self.counts.shape[0]
        out = np.zeros((n_steps, n_workloads), dtype=np.int64)
        step = np.repeat(np.arange(n_steps), self.counts)
        np.add.at(out, (step, self.wl), 1)
        return out


class FleetScheduler:
    """Host handle over (``SchedParams``, ``SchedState``) for one pool.

    Construction compiles the workload tables into stacked arrays and
    fits the per-trace-row harvest forecaster; ``submit`` / ``dispatch``
    / ``collect`` evaluate the shared control-plane expressions with
    ``xp=numpy`` against the pool's live state (the reference path). The
    fused JAX path bypasses these methods and runs the identical
    expressions inside the device scan.
    """

    def __init__(self, pool: FleetWorkerPool,
                 workloads: list[FleetWorkload], *,
                 max_queue: int = 4096,
                 shed_after_s: float = 30.0,
                 max_batch: int = 4,
                 max_retries: int = 2,
                 grace_s: float = 20.0,
                 straggler: StragglerPolicy | None = None,
                 sched: str = "reactive",
                 lookahead_s: float = 5.0,
                 forecaster: str = "ou",
                 trace_families: list[str] | None = None,
                 arp_order: int = 3,
                 lat_bins: int = 64):
        if pool.mode != "dispatch":
            raise ValueError("scheduler needs a dispatch-mode pool")
        self.pool = pool
        self.workloads = workloads
        straggler = straggler or StragglerPolicy()
        self.params = _sched.make_sched_params(
            pool.params, workloads, max_queue=max_queue,
            shed_after_s=shed_after_s, max_batch=max_batch,
            max_retries=max_retries, grace_s=grace_s,
            deadline_factor=straggler.deadline_factor, sched=sched,
            lookahead_s=lookahead_s, forecaster=forecaster,
            trace_families=trace_families, arp_order=arp_order,
            lat_bins=lat_bins)
        self.state = _sched.make_sched_state(self.params)

    # -- state plumbing ------------------------------------------------------

    def _ss(self) -> _sched.SS:
        return _sched.SS(*sched_state_as_tuple(self.state))

    def _store(self, ss) -> None:
        self.state = sched_state_from_tuple(tuple(ss))

    @property
    def backlog(self) -> int:
        """Requests currently queued (all workloads)."""
        return int(self.state.q_len.sum())

    @property
    def inflight_count(self) -> int:
        """Requests currently assigned to (pending or running on) workers."""
        return int(self.state.f_n.sum())

    def summary(self, duration_s: float) -> dict:
        return sched_summary(self.params, self.state, duration_s,
                             self.pool, [w.name for w in self.workloads])

    # -- intake --------------------------------------------------------------

    def submit(self, t: float, workload_ids: np.ndarray) -> None:
        """Admit arrivals; reject beyond the global queue bound."""
        counts = np.bincount(np.asarray(workload_ids, dtype=np.int64),
                             minlength=self.params.W).astype(np.int64)
        self._store(_sched.admit(self.params, self._ss(), counts,
                                 float(t), np))

    # -- dispatch ------------------------------------------------------------

    def dispatch(self, t: float, i: int | None = None) -> int:
        """Shed stale work, then route queued requests to capable workers
        (richest planning budget first). Returns requests assigned."""
        sp = self.params
        p = self.pool.params
        s = self.pool.state
        if i is None:
            i = int(round(t / p.dt))
        ss = _sched.shed(sp, self._ss(), float(t), np)
        budget_now = backend_numpy.usable_energy(p, s)
        pw_lags = _sched.power_lags(p.power, p.trace_index, i, p.T,
                                    sp.fc_order, phase=p.phase, xp=np)
        budget_plan = _sched.plan_budget(sp, budget_now, pw_lags, p.eff,
                                         np)
        dispatchable = s.on & ~s.has_work & ~s.p_pending
        ss, a = _sched.dispatch(sp, ss, dispatchable, budget_now,
                                budget_plan, float(t), np)
        s.p_pending = s.p_pending | a.mask
        s.p_wl = np.where(a.mask, a.wl, s.p_wl)
        s.p_units = np.where(a.mask, a.units, s.p_units)
        s.p_batch = np.where(a.mask, np.maximum(a.batch, 1), s.p_batch)
        s.p_t_assigned = np.where(a.mask, float(t), s.p_t_assigned)
        self._store(ss)
        return int(a.batch.sum())

    # -- harvest results / losses -------------------------------------------

    def collect(self, t: float, evict: bool = False) -> None:
        """Retire the pool's emit/loss events through the array control
        plane; optionally run the straggler-eviction pass."""
        n = self.params.n
        emit = np.zeros(n, dtype=bool)
        lost = np.zeros(n, dtype=bool)
        units = np.zeros(n, dtype=np.int64)
        for ev in self.pool.pop_events():
            w = int(ev[2])
            if ev[0] == EMIT:
                emit[w] = True
                units[w] = int(ev[4])
            else:
                lost[w] = True
        ss = _sched.collect(self.params, self._ss(), emit, lost, units,
                            float(t), np)
        if evict:
            ss, evm = _sched.evict(self.params, ss, float(t), np)
            s = self.pool.state
            s.p_pending = s.p_pending & ~evm
            s.has_work = s.has_work & ~evm
        self._store(ss)


def run_fleet(pool: FleetWorkerPool, sched: FleetScheduler,
              stream: RequestStream, n_steps: int, *,
              dispatch_every: int = 10, obs=None) -> dict:
    """Drive arrivals -> control plane -> device physics -> collection.

    With a NumPy pool the loop advances tick-by-tick on the host (the
    reference cadence). With a JAX pool the *entire* serve trace —
    arrivals, admission, routing, batching, shedding, eviction, and the
    device physics — runs as one fused ``lax.scan`` launch
    (``backend_jax.run_serve``): the arrival counts matrix is the scan
    input, the dispatch/evict passes fire under a ``lax.cond`` every
    ``dispatch_every`` ticks, and only the final states return to the
    host. Both paths evaluate the same control-plane expressions and
    agree exactly on all discrete counts.

    ``obs`` (a ``repro.obs.FleetObs``, or None) instruments the run:
    the NumPy loop calls its snapshot hooks around each tick, the JAX
    path threads its arrays through the scan carry — both fill the same
    int64 channels bit-exactly, and neither perturbs the serve results.
    """
    dt = pool.dt
    if getattr(pool, "backend", "numpy") == "jax":
        arrivals = stream.counts_matrix(sched.params.W)[:n_steps]
        pool.run_serve(sched, arrivals, dispatch_every=dispatch_every,
                       obs=obs)
        return sched.summary(n_steps * dt)
    for i in range(n_steps):
        t = i * dt
        if obs is not None:
            obs.host_begin(pool.state, sched.state)
        wls = stream.arrivals(i)
        if wls.size:
            sched.submit(t, wls)
        tick = i % dispatch_every == 0
        if tick:
            sched.dispatch(t, i)
            if obs is not None:
                obs.host_after_dispatch(pool.state)
        pool.step(i)
        if obs is not None:
            obs.host_before_evict(pool.state)
        sched.collect(t, evict=tick)
        if obs is not None:
            obs.host_end(i, tick, pool.state, sched.state)
    return sched.summary(n_steps * dt)
