"""Central energy-aware dispatcher for the fleet worker pool.

The scheduler owns the global request stream and answers, every dispatch
tick, the fleet version of the paper's per-sample question: *which worker
should run this request, at which knob setting, so the result is emitted
within the worker's current power cycle?*

Mechanisms (each maps to a single-device concept):

- **Admission control** — a bounded queue; arrivals beyond ``max_queue``
  are rejected outright (the SMART "skip the round" rule, applied at the
  fleet's front door).
- **Energy-proportional routing** — idle workers are ranked by usable
  capacitor energy; the oldest queued request goes to the richest worker,
  i.e. to the worker whose budget affords the highest expected-accuracy
  knob. Per-worker knob choice literally reuses ``core.policies``
  (``Smart`` admission at the workload's accuracy floor, greedy
  refinement via ``CostTable``).
- **Batching** — several queued requests of one workload can ride one
  power cycle, paying the fixed (acquisition/setup) and emission cost
  once; the batch size is the largest that still affords the floor knob.
- **Load shedding** — queued requests older than ``shed_after_s`` are
  dropped: a stale approximate answer is worth less than no answer, and
  the energy is better spent on fresh requests (the paper processes the
  *newest* pending sample for the same reason).
- **Straggler eviction** — assignments that outlive the deadline implied
  by ``runtime.straggler.StragglerPolicy`` (the worker browned out before
  acquiring, or recharges too slowly) are evicted and requeued, exactly
  like a slow shard being skipped for a step; ``runtime.preemption``'s
  lost-work bookkeeping shows up here as the retry budget.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core.policies import Greedy, Policy, Smart
from repro.fleet.metrics import FleetMetrics, RequestRecord
from repro.fleet.worker import EMIT, LOST, FleetWorkerPool
from repro.fleet.workloads import FleetWorkload
from repro.runtime.straggler import StragglerPolicy


@dataclasses.dataclass
class Request:
    rid: int
    workload: int
    t_arrival: float
    retries: int = 0
    t_assigned: float = -1.0


class RequestStream:
    """Deterministic Poisson arrivals with a workload mix."""

    def __init__(self, rate_rps: float, mix: np.ndarray, n_steps: int,
                 dt: float, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.counts = rng.poisson(rate_rps * dt, size=n_steps)
        total = int(self.counts.sum())
        mix = np.asarray(mix, dtype=np.float64)
        self.wl = rng.choice(mix.shape[0], size=total, p=mix / mix.sum())
        self.offsets = np.concatenate([[0], np.cumsum(self.counts)])

    def arrivals(self, i: int) -> np.ndarray:
        """Workload indices of the requests arriving at step ``i``."""
        return self.wl[self.offsets[i]:self.offsets[i + 1]]


class FleetScheduler:
    def __init__(self, pool: FleetWorkerPool,
                 workloads: list[FleetWorkload], *,
                 max_queue: int = 4096,
                 shed_after_s: float = 30.0,
                 max_batch: int = 4,
                 max_retries: int = 2,
                 grace_s: float = 20.0,
                 straggler: StragglerPolicy | None = None):
        if pool.mode != "dispatch":
            raise ValueError("scheduler needs a dispatch-mode pool")
        self.pool = pool
        self.workloads = workloads
        self.max_queue = max_queue
        self.shed_after_s = shed_after_s
        self.max_batch = max_batch
        self.max_retries = max_retries
        self.grace_s = grace_s
        self.straggler = straggler or StragglerPolicy()
        self.queues: list[collections.deque[Request]] = [
            collections.deque() for _ in workloads]
        # per-workload admission policy: SMART at the workload's floor
        # (Greedy when no floor), plus cached cost prefixes for batching
        self.admission: list[Policy] = [
            Smart(w.floor) if w.floor > 0 else Greedy() for w in workloads]
        self._cu = [np.concatenate([[0.0], np.cumsum(w.costs.unit_costs)])
                    for w in workloads]
        self.inflight: dict[int, tuple[list[Request], float, int]] = {}
        self.metrics = FleetMetrics()
        self._ticket = 0
        self._rid = 0

    # -- intake --------------------------------------------------------------

    def submit(self, t: float, workload_ids: np.ndarray) -> None:
        """Admit arrivals; reject beyond the global queue bound."""
        backlog = sum(len(q) for q in self.queues)
        for wl in workload_ids:
            self.metrics.submitted += 1
            if backlog >= self.max_queue:
                self.metrics.rejected += 1
                continue
            self.queues[int(wl)].append(Request(self._rid, int(wl), t))
            self._rid += 1
            backlog += 1

    # -- dispatch ------------------------------------------------------------

    def dispatch(self, t: float) -> int:
        """Shed stale work, then route queued requests to capable workers.
        Returns the number of requests assigned this tick."""
        self._shed(t)
        if not any(self.queues):
            return 0
        idle = np.nonzero(self.pool.dispatchable())[0]
        if idle.size == 0:
            return 0
        usable = self.pool.usable_energy()
        order = idle[np.argsort(-usable[idle])]  # richest worker first
        assigned = 0
        ptr = 0
        while ptr < order.size:
            # oldest head request across workload queues (FIFO fairness)
            heads = [(q[0].t_arrival, wl) for wl, q in enumerate(self.queues)
                     if q]
            if not heads:
                break
            heads.sort()
            w = int(order[ptr])
            budget = float(usable[w])
            placed = 0
            for _, wl in heads:
                placed = self._try_assign(w, wl, budget, t)
                if placed:
                    assigned += placed
                    break
            if not placed:
                # the RICHEST remaining worker cannot afford any queue's
                # floor knob; poorer workers cannot either — stop here
                break
            ptr += 1
        return assigned

    def _try_assign(self, w: int, wl: int, budget: float, t: float) -> int:
        """Assign a batch from queue ``wl`` to worker ``w`` if the worker's
        budget affords the workload's floor knob; returns the batch size
        (0: not affordable)."""
        wk = self.workloads[wl]
        d = self.admission[wl].decide(budget, wk.costs, wk.accuracy)
        if d.skipped:
            return 0
        p_req = d.initial_units
        cu = self._cu[wl]
        overhead = wk.costs.fixed_cost + wk.costs.emit_cost
        spendable = budget - overhead
        q = self.queues[wl]
        # batch: how many floor-knob requests ride this power cycle?
        if cu[p_req] > 0:
            b = int(spendable // cu[p_req])
        else:
            b = self.max_batch
        b = max(1, min(b, self.max_batch, len(q)))
        # greedy refinement: the largest per-request knob the batch affords
        if d.refine_greedily:
            u = int(np.searchsorted(cu, spendable / b, side="right") - 1)
            u = max(p_req, min(u, wk.costs.n_units))
        else:
            u = p_req
        if u <= 0:
            return 0  # zero-work assignment: nothing worth emitting
        reqs = [q.popleft() for _ in range(b)]
        for r in reqs:
            r.t_assigned = t
        ticket = self._ticket
        self._ticket += 1
        self.pool.assign(np.array([w]), np.array([ticket]),
                         np.array([wl]), np.array([u]), np.array([b]), t)
        self.inflight[ticket] = (reqs, t, w)
        return b

    # -- harvest results / losses -------------------------------------------

    def collect(self, t: float, evict: bool = False) -> None:
        for ev in self.pool.pop_events():
            kind, t_ev, w, ticket = ev[0], ev[1], ev[2], ev[3]
            entry = self.inflight.pop(ticket, None)
            if entry is None:
                continue
            reqs, _, _ = entry
            if kind == EMIT:
                _, _, _, _, units_done, req_units, batch = ev
                full = units_done // req_units if req_units > 0 else len(reqs)
                part = units_done % req_units if req_units > 0 else 0
                wl = reqs[0].workload
                acc = self.workloads[wl].accuracy
                for j, r in enumerate(reqs):
                    if j < full:
                        units = req_units
                    elif j == full and part > 0:
                        units = part  # anytime partial result, still emitted
                    else:
                        self._retry(r, t)
                        continue
                    self.metrics.observe_completion(RequestRecord(
                        r.rid, r.workload, r.t_arrival, r.t_assigned, t_ev,
                        int(units), int(w), int(batch),
                        float(acc[int(units)])))
            elif kind == LOST:
                for r in reqs:
                    self._retry(r, t)
        if evict:
            self._evict_stragglers(t)

    def _retry(self, r: Request, t: float) -> None:
        r.retries += 1
        if r.retries > self.max_retries:
            self.metrics.lost += 1
        else:
            self.metrics.requeued += 1
            self.queues[r.workload].appendleft(r)

    def _shed(self, t: float) -> None:
        for q in self.queues:
            while q and t - q[0].t_arrival > self.shed_after_s:
                q.popleft()
                self.metrics.shed += 1

    def _evict_stragglers(self, t: float) -> None:
        """Revoke assignments that outlived their service deadline: the
        worker browned out before acquiring, or recharges too slowly."""
        active_p = self.pool.mcu.active_power_w
        stale: list[tuple[int, int]] = []
        for ticket, (reqs, t_assigned, w) in self.inflight.items():
            wl = reqs[0].workload
            wk = self.workloads[wl]
            est = (wk.costs.fixed_cost + wk.costs.emit_cost
                   + len(reqs) * self._cu[wl][-1]) / active_p
            if t - t_assigned > self.grace_s + self.straggler.deadline_s(est):
                stale.append((ticket, w))
        for ticket, w in stale:
            revoked = self.pool.evict(np.array([w]))
            if ticket not in revoked:
                continue  # raced with an emit/loss; next collect settles it
            reqs, _, _ = self.inflight.pop(ticket)
            self.metrics.evicted += len(reqs)
            for r in reqs:
                self._retry(r, t)


def run_fleet(pool: FleetWorkerPool, sched: FleetScheduler,
              stream: RequestStream, n_steps: int, *,
              dispatch_every: int = 10) -> dict:
    """Drive arrivals -> dispatch -> device physics -> collection.

    With a NumPy pool the loop advances tick-by-tick (the reference
    cadence). With a JAX pool the device physics run as fused macro-steps:
    one ``lax.scan`` launch per scheduler interval, with arrivals logged
    at their true per-tick times, assignments made at the macro boundary
    (exactly where the per-tick loop makes them, since ``dispatch`` only
    fires every ``dispatch_every`` ticks), and the scan's fixed-capacity
    event arrays collected once per macro-step.
    """
    dt = pool.dt
    names = [w.name for w in sched.workloads]
    if getattr(pool, "backend", "numpy") == "jax":
        for i0 in range(0, n_steps, dispatch_every):
            k = min(dispatch_every, n_steps - i0)
            sched.submit(i0 * dt, stream.arrivals(i0))
            sched.dispatch(i0 * dt)
            for i in range(i0 + 1, i0 + k):
                wls = stream.arrivals(i)
                if wls.size:
                    sched.submit(i * dt, wls)
            pool.step_macro(i0, k)
            sched.collect((i0 + k - 1) * dt, evict=True)
        return sched.metrics.summary(n_steps * dt, pool, names)
    for i in range(n_steps):
        t = i * dt
        wls = stream.arrivals(i)
        if wls.size:
            sched.submit(t, wls)
        tick = i % dispatch_every == 0
        if tick:
            sched.dispatch(t)
        pool.step(i)
        sched.collect(t, evict=tick)
    return sched.metrics.summary(n_steps * dt, pool, names)
