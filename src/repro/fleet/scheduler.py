"""Forecast-aware fleet dispatcher: a thin host frontend over the
array-native control plane (``repro.fleet.sched``).

The scheduler owns the global request stream and answers, every dispatch
tick, the fleet version of the paper's per-sample question: *which worker
should run this request, at which knob setting, so the result is emitted
within the worker's current power cycle?* Since PR 3 the answer is
computed by pure struct-of-arrays ops (queue ring-buffers, cumulative-sum
batching, stable-sort routing) instead of a per-request Python object
loop, so the same expressions run in two modes:

- ``backend="numpy"`` pools: :class:`FleetScheduler` drives the array ops
  tick-by-tick on the host — the bit-exact reference cadence;
- ``backend="jax"`` pools: :func:`run_fleet` hands the whole serve trace
  to ``backend_jax.run_serve`` — workers **and** scheduler fused into a
  single ``lax.scan`` device launch with no per-macro-step host
  round-trips.

Routing is *forecast-aware* (``sched="forecast"``): workers are ranked —
and batches sized — by the conditional expectation of usable energy over
the next ``lookahead_s`` window instead of instantaneous charge, under a
*pluggable* harvest forecaster (``repro.core.forecast``): the closed-form
OU mean reversion, the occlusion/burst regime models, a learned AR(p)
fit, or per-row automatic selection (``forecaster="auto"``, matched to
each row's trace family). ``sched="reactive"`` is the PR-1 behavior.
"""
from __future__ import annotations

import collections

import numpy as np

from repro.fleet import backend_numpy, sched as _sched
from repro.fleet.metrics import _hist_percentile, sched_summary
from repro.fleet.state import (STATE_FIELDS, sched_state_as_tuple,
                               sched_state_from_tuple)
from repro.fleet.worker import EMIT, FleetWorkerPool
from repro.fleet.workloads import FleetWorkload
from repro.runtime.straggler import StragglerPolicy


class RequestStream:
    """Deterministic Poisson arrivals with a workload mix."""

    def __init__(self, rate_rps: float, mix: np.ndarray, n_steps: int,
                 dt: float, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.counts = rng.poisson(rate_rps * dt, size=n_steps)
        total = int(self.counts.sum())
        mix = np.asarray(mix, dtype=np.float64)
        self.wl = rng.choice(mix.shape[0], size=total, p=mix / mix.sum())
        self.offsets = np.concatenate([[0], np.cumsum(self.counts)])

    def arrivals(self, i: int) -> np.ndarray:
        """Workload indices of the requests arriving at step ``i``."""
        return self.wl[self.offsets[i]:self.offsets[i + 1]]

    def counts_matrix(self, n_workloads: int) -> np.ndarray:
        """(n_steps, W) per-tick arrival counts — the array-native form
        the fused serve scan consumes as its ``lax.scan`` input."""
        n_steps = self.counts.shape[0]
        out = np.zeros((n_steps, n_workloads), dtype=np.int64)
        step = np.repeat(np.arange(n_steps), self.counts)
        np.add.at(out, (step, self.wl), 1)
        return out


class FleetScheduler:
    """Host handle over (``SchedParams``, ``SchedState``) for one pool.

    Construction compiles the workload tables into stacked arrays and
    fits the per-trace-row harvest forecaster; ``submit`` / ``dispatch``
    / ``collect`` evaluate the shared control-plane expressions with
    ``xp=numpy`` against the pool's live state (the reference path). The
    fused JAX path bypasses these methods and runs the identical
    expressions inside the device scan.
    """

    def __init__(self, pool: FleetWorkerPool,
                 workloads: list[FleetWorkload], *,
                 max_queue: int = 4096,
                 shed_after_s: float = 30.0,
                 max_batch: int = 4,
                 max_retries: int = 2,
                 grace_s: float = 20.0,
                 straggler: StragglerPolicy | None = None,
                 sched: str = "reactive",
                 lookahead_s: float = 5.0,
                 forecaster: str = "ou",
                 trace_families: list[str] | None = None,
                 arp_order: int = 3,
                 forecaster_fit: str = "full",
                 lat_bins: int = 64,
                 shards: int = 1,
                 rebalance_every: int = 0,
                 rebalance_max: int = 8):
        if pool.mode != "dispatch":
            raise ValueError("scheduler needs a dispatch-mode pool")
        self.pool = pool
        self.workloads = workloads
        straggler = straggler or StragglerPolicy()
        self.params = _sched.make_sched_params(
            pool.params, workloads, max_queue=max_queue,
            shed_after_s=shed_after_s, max_batch=max_batch,
            max_retries=max_retries, grace_s=grace_s,
            deadline_factor=straggler.deadline_factor, sched=sched,
            lookahead_s=lookahead_s, forecaster=forecaster,
            trace_families=trace_families, arp_order=arp_order,
            forecaster_fit=forecaster_fit,
            lat_bins=lat_bins, shards=shards,
            rebalance_every=rebalance_every,
            rebalance_max=rebalance_max,
            persist=pool.params.persist,
            fram_write_j_per_byte=pool.mcu.fram_write_j_per_byte,
            fram_read_j_per_byte=pool.mcu.fram_read_j_per_byte)
        self.state = _sched.make_sched_state(self.params)
        # causal refit machinery: windowed sufficient statistics over the
        # observed harvest prefix (repro.core.forecast.CausalFitState),
        # refreshed by refit_forecast at streaming chunk boundaries
        self.fit_state = None
        self.observed_ticks = 0
        if forecaster_fit == "causal" and sched == "forecast":
            from repro.core.forecast import CausalFitState
            self.fit_state = CausalFitState(
                forecaster, pool.params.power.shape[0],
                arp_order=arp_order, families=trace_families)

    # -- state plumbing ------------------------------------------------------

    def _ss(self) -> _sched.SS:
        return _sched.SS(*sched_state_as_tuple(self.state))

    def _store(self, ss) -> None:
        self.state = sched_state_from_tuple(tuple(ss))

    @property
    def backlog(self) -> int:
        """Requests currently queued (all workloads)."""
        return int(self.state.q_len.sum())

    @property
    def inflight_count(self) -> int:
        """Requests currently assigned to (pending or running on) workers."""
        return int(self.state.f_n.sum())

    def refit_forecast(self, upto_tick: int) -> bool:
        """Causal refit: absorb harvest columns ``[observed, upto_tick)``
        into the sufficient statistics and swap the compiled forecast
        tables in ``self.params`` for a fit on exactly that prefix.

        Prefix-only by construction — samples at trace tick
        ``>= upto_tick`` are never read (pinned by the future-mutation
        test in tests/test_streaming.py). The replacement keeps every
        non-``FC_*`` field identical (``sched_params_compatible``), so
        the fused scan's compiled functions stay valid and the new
        tables flow in as runtime arguments. Returns True iff the
        tables changed (i.e. the scheduler was built with
        ``forecaster_fit="causal"`` and ``sched="forecast"``)."""
        if self.fit_state is None:
            return False
        import dataclasses
        p = self.pool.params
        upto = min(int(upto_tick), p.T)
        if upto > self.observed_ticks:
            self.fit_state.update(p.power[:, self.observed_ticks:upto])
            self.observed_ticks = upto
        rf = self.fit_state.compile(
            self.params.lookahead_ticks).take(p.trace_index)
        self.params = dataclasses.replace(
            self.params, FC_MU=rf.MU, FC_W=rf.W, FC_THRESH=rf.THRESH,
            FC_HI=rf.HI, FC_LO=rf.LO, FC_MODEL=rf.model)
        return True

    def summary(self, duration_s: float) -> dict:
        # merged_sched_view sums sharded (K, ...) accounting fields over
        # the shard axis (identity for the unsharded state)
        return sched_summary(self.params,
                             _sched.merged_sched_view(self.state),
                             duration_s, self.pool,
                             [w.name for w in self.workloads])

    # -- intake --------------------------------------------------------------

    def submit(self, t: float, workload_ids: np.ndarray) -> None:
        """Admit arrivals; reject beyond the global queue bound."""
        counts = np.bincount(np.asarray(workload_ids, dtype=np.int64),
                             minlength=self.params.W).astype(np.int64)
        self._store(_sched.admit(self.params, self._ss(), counts,
                                 float(t), np))

    # -- dispatch ------------------------------------------------------------

    def dispatch(self, t: float, i: int | None = None) -> int:
        """Shed stale work, then route queued requests to capable workers
        (richest planning budget first). Returns requests assigned."""
        sp = self.params
        p = self.pool.params
        s = self.pool.state
        if i is None:
            i = int(round(t / p.dt))
        ss = _sched.shed(sp, self._ss(), float(t), np)
        budget_now = backend_numpy.usable_energy(p, s)
        pw_lags = _sched.power_lags(p.power, p.trace_index, i, p.T,
                                    sp.fc_order, phase=p.phase, xp=np)
        budget_plan = _sched.plan_budget(sp, budget_now, pw_lags, p.eff,
                                         np)
        dispatchable = s.on & ~s.has_work & ~s.p_pending
        ss, a = _sched.dispatch(sp, ss, dispatchable, budget_now,
                                budget_plan, float(t), np)
        s.p_pending = s.p_pending | a.mask
        s.p_wl = np.where(a.mask, a.wl, s.p_wl)
        s.p_units = np.where(a.mask, a.units, s.p_units)
        s.p_batch = np.where(a.mask, np.maximum(a.batch, 1), s.p_batch)
        s.p_t_assigned = np.where(a.mask, float(t), s.p_t_assigned)
        self._store(ss)
        return int(a.batch.sum())

    # -- harvest results / losses -------------------------------------------

    def collect(self, t: float, evict: bool = False) -> None:
        """Retire the pool's emit/loss events through the array control
        plane; optionally run the straggler-eviction pass."""
        n = self.params.n
        emit = np.zeros(n, dtype=bool)
        lost = np.zeros(n, dtype=bool)
        units = np.zeros(n, dtype=np.int64)
        for ev in self.pool.pop_events():
            w = int(ev[2])
            if ev[0] == EMIT:
                emit[w] = True
                units[w] = int(ev[4])
            else:
                lost[w] = True
        ss = _sched.collect(self.params, self._ss(), emit, lost, units,
                            float(t), np)
        if evict:
            ss, evm = _sched.evict(self.params, ss, float(t), np)
            s = self.pool.state
            s.p_pending = s.p_pending & ~evm
            s.has_work = s.has_work & ~evm
        self._store(ss)


def run_fleet(pool: FleetWorkerPool, sched: FleetScheduler,
              stream: RequestStream, n_steps: int, *,
              dispatch_every: int = 10, obs=None) -> dict:
    """Drive arrivals -> control plane -> device physics -> collection.

    With a NumPy pool the loop advances tick-by-tick on the host (the
    reference cadence). With a JAX pool the *entire* serve trace —
    arrivals, admission, routing, batching, shedding, eviction, and the
    device physics — runs as one fused ``lax.scan`` launch
    (``backend_jax.run_serve``): the arrival counts matrix is the scan
    input, the dispatch/evict passes fire under a ``lax.cond`` every
    ``dispatch_every`` ticks, and only the final states return to the
    host. Both paths evaluate the same control-plane expressions and
    agree exactly on all discrete counts.

    ``obs`` (a ``repro.obs.FleetObs``, or None) instruments the run:
    the NumPy loop calls its snapshot hooks around each tick, the JAX
    path threads its arrays through the scan carry — both fill the same
    int64 channels bit-exactly, and neither perturbs the serve results.
    """
    dt = pool.dt
    if getattr(pool, "backend", "numpy") == "jax":
        arrivals = stream.counts_matrix(sched.params.W)[:n_steps]
        pool.run_serve(sched, arrivals, dispatch_every=dispatch_every,
                       obs=obs)
        return sched.summary(n_steps * dt)
    if sched.params.shards > 1:
        return _run_fleet_numpy_sharded(pool, sched, stream, n_steps,
                                        dispatch_every, obs)
    for i in range(n_steps):
        t = i * dt
        if obs is not None:
            obs.host_begin(pool.state, sched.state)
        wls = stream.arrivals(i)
        if wls.size:
            sched.submit(t, wls)
        tick = i % dispatch_every == 0
        if tick:
            sched.dispatch(t, i)
            if obs is not None:
                obs.host_after_dispatch(pool.state)
        pool.step(i)
        if obs is not None:
            obs.host_before_evict(pool.state)
        sched.collect(t, evict=tick)
        if obs is not None:
            obs.host_end(i, tick, pool.state, sched.state)
    return sched.summary(n_steps * dt)


_FS = collections.namedtuple("_FS", STATE_FIELDS)


def _slice_state(s, sl: slice) -> _FS:
    """One shard's view of the (N,) struct-of-arrays device state."""
    return _FS(*(getattr(s, f)[sl] for f in STATE_FIELDS))


class _ShardedHostServe:
    """NumPy host twin of the sharded serve scan (``--mesh-fleet K``),
    restructured around :meth:`window` so the streaming loop can drive
    it chunk-by-chunk with full state carried across chunk boundaries.

    The device physics stays full-fleet — the tick is embarrassingly
    parallel over workers, so one ``pool.step`` per tick is already
    bit-identical to K shard-local ticks. Only the control plane loops
    the K contiguous shard slices: per-shard admission (deterministic
    ``split_counts`` arrival split — elementwise, so splitting each
    chunk equals slicing the full split), shed/plan/dispatch/collect/
    evict against each shard's params view, the all-integer
    work-stealing exchange via :func:`repro.fleet.sched.rebalance_host`,
    and (in tele mode) K per-shard telemetry states summed into
    ``obs.tele`` at each window end — every channel is an int64
    scatter-add, so the per-window shard sums accumulate to exactly the
    whole-trace counters. This is the reference the traced
    ``shard_map``/``vmap`` path is gated against bit-for-bit.

    Each :meth:`window` call re-reads ``sched.params`` (a causal refit
    between chunks swaps the ``FC_*`` tables) and restacks the
    per-shard scheduler states into ``sched.state`` on exit, so the
    carried state is exactly the (K, ...) stacked form the fused scan
    uses.
    """

    def __init__(self, pool: FleetWorkerPool, sched: FleetScheduler,
                 dispatch_every: int, obs):
        sp = sched.params
        p = pool.params
        if sp.rebalance_every and (sp.rebalance_every % dispatch_every):
            raise ValueError(
                f"rebalance_every={sp.rebalance_every} ticks must be a "
                f"positive multiple of dispatch_every={dispatch_every}:"
                " the work-stealing exchange runs inside the dispatch "
                "pass")
        if obs is not None and obs.op.mode != "tele":
            raise ValueError(
                "--obs trace keeps a global per-worker event ring and "
                "is not supported under --mesh-fleet > 1; use --obs "
                "tele (windowed counters reduce exactly across shards)")
        self.pool = pool
        self.sched = sched
        self.dispatch_every = dispatch_every
        self.obs = obs
        self.K = sp.shards
        self.ns = p.n // self.K
        self.sls = [slice(s * self.ns, (s + 1) * self.ns)
                    for s in range(self.K)]

    def window(self, counts: np.ndarray, i0: int) -> None:
        """Serve ticks ``[i0, i0 + counts.shape[0])`` with per-tick
        arrival counts ``counts`` ((k, W) int64), mutating pool and
        scheduler state in place."""
        pool, sched, obs = self.pool, self.sched, self.obs
        K, ns, sls = self.K, self.ns, self.sls
        dispatch_every = self.dispatch_every
        sp = sched.params  # re-read: causal refits swap the FC_* tables
        p = pool.params
        dt = pool.dt
        sps = [_sched.shard_sched_params(sp, s) for s in range(K)]
        split = _sched.split_counts(np.asarray(counts, np.int64), K)
        st = sched.state
        sss = [_sched.SS(*(np.asarray(getattr(st, f))[s]
                           for f in _sched.SCHED_FIELDS))
               for s in range(K)]
        dev = pool.state
        if obs is not None:
            from repro.obs import telemetry as O
            from repro.obs.state import (init_tele, tele_as_tuple,
                                         tele_from_tuple)
            base = tele_as_tuple(init_tele(obs.op))
            teles = [tuple(np.zeros_like(np.asarray(x)) for x in base)
                     for _ in range(K)]
        for j in range(split.shape[1]):
            i = i0 + j
            t = i * dt
            is_tick = i % dispatch_every == 0
            if obs is not None:
                begins = [(O.dev_snap(_slice_state(dev, sl), copy=True),
                           O.sched_snap(sss[s], np))
                          for s, sl in enumerate(sls)]
                assigns = [np.zeros(ns, dtype=bool) for _ in range(K)]
                assign_wls = [np.zeros(ns, dtype=np.int64)
                              for _ in range(K)]
            for s in range(K):
                sss[s] = _sched.admit(sps[s], sss[s], split[s, j], t,
                                      np)
            if is_tick:
                budget_now = backend_numpy.usable_energy(p, dev)
                plans = []
                for s, sl in enumerate(sls):
                    sss[s] = _sched.shed(sps[s], sss[s], t, np)
                    pw_lags = _sched.power_lags(
                        p.power, p.trace_index[sl], i, p.T, sp.fc_order,
                        phase=None if p.phase is None else p.phase[sl],
                        xp=np)
                    plans.append(_sched.plan_budget(
                        sps[s], budget_now[sl], pw_lags, p.eff, np))
                if sp.rebalance_every and i % sp.rebalance_every == 0:
                    sss = _sched.rebalance_host(sps, sss, plans)
                mask_f = np.zeros(p.n, dtype=bool)
                wl_f = np.zeros(p.n, dtype=np.int64)
                units_f = np.zeros(p.n, dtype=np.int64)
                batch_f = np.zeros(p.n, dtype=np.int64)
                for s, sl in enumerate(sls):
                    dispatchable = (dev.on & ~dev.has_work
                                    & ~dev.p_pending)[sl]
                    sss[s], a = _sched.dispatch(
                        sps[s], sss[s], dispatchable, budget_now[sl],
                        plans[s], t, np)
                    mask_f[sl] = a.mask
                    wl_f[sl] = a.wl
                    units_f[sl] = a.units
                    batch_f[sl] = a.batch
                # one full-width write round, the exact expressions (and
                # dtype promotions) of FleetScheduler.dispatch
                dev.p_pending = dev.p_pending | mask_f
                dev.p_wl = np.where(mask_f, wl_f, dev.p_wl)
                dev.p_units = np.where(mask_f, units_f, dev.p_units)
                dev.p_batch = np.where(mask_f, np.maximum(batch_f, 1),
                                       dev.p_batch)
                dev.p_t_assigned = np.where(mask_f, float(t),
                                            dev.p_t_assigned)
                if obs is not None:
                    for s, sl in enumerate(sls):
                        assigns[s] = (dev.p_pending[sl]
                                      & ~begins[s][0].p_pending)
                        assign_wls[s] = dev.p_wl[sl].copy()
            pool.step(i)
            if obs is not None:
                pre_evict = dev.p_pending | dev.has_work
            emit = np.zeros(p.n, dtype=bool)
            lost = np.zeros(p.n, dtype=bool)
            units = np.zeros(p.n, dtype=np.int64)
            for ev in pool.pop_events():
                w = int(ev[2])
                if ev[0] == EMIT:
                    emit[w] = True
                    units[w] = int(ev[4])
                else:
                    lost[w] = True
            for s, sl in enumerate(sls):
                sss[s] = _sched.collect(sps[s], sss[s], emit[sl],
                                        lost[sl], units[sl], t, np)
            if is_tick:
                evm_f = np.zeros(p.n, dtype=bool)
                for s, sl in enumerate(sls):
                    sss[s], evm = _sched.evict(sps[s], sss[s], t, np)
                    evm_f[sl] = evm
                dev.p_pending = dev.p_pending & ~evm_f
                dev.has_work = dev.has_work & ~evm_f
            if obs is not None:
                for s, sl in enumerate(sls):
                    col = ((i % p.T) if p.phase is None
                           else (i + p.phase[sl]) % p.T)
                    pw = p.power[p.trace_index[sl], col]
                    evict_mask = (pre_evict[sl]
                                  & ~(dev.p_pending[sl]
                                      | dev.has_work[sl]))
                    teles[s], _ = O.obs_tick(
                        obs.op, sps[s], teles[s], None, i=i, j=i,
                        is_tick=is_tick, pw=pw, eff=p.eff, dt=p.dt,
                        b=begins[s][0], sb=begins[s][1],
                        assign_mask=assigns[s],
                        assign_wl=assign_wls[s],
                        evict_mask=evict_mask,
                        fs=_slice_state(dev, sl), ss=sss[s],
                        power=p.power, cs=obs.cs,
                        trace_index=p.trace_index[sl],
                        phase=None if p.phase is None else p.phase[sl],
                        T=p.T, xp=np)
        sched.state = sched_state_from_tuple(tuple(
            np.stack([np.asarray(getattr(ss_, f)) for ss_ in sss])
            for f in _sched.SCHED_FIELDS))
        if obs is not None:
            obs.tele = tele_from_tuple(tuple(
                np.asarray(o) + sum(np.asarray(tl[k]) for tl in teles)
                for k, o in enumerate(tele_as_tuple(obs.tele))))


def _run_fleet_numpy_sharded(pool: FleetWorkerPool,
                             sched: FleetScheduler,
                             stream: RequestStream, n_steps: int,
                             dispatch_every: int, obs) -> dict:
    """Whole-trace entry over :class:`_ShardedHostServe` — one window
    covering the full serve trace (the offline reference cadence)."""
    serve = _ShardedHostServe(pool, sched, dispatch_every, obs)
    serve.window(stream.counts_matrix(sched.params.W)[:n_steps], 0)
    return sched.summary(n_steps * pool.dt)


def _run_fleet_numpy_window(pool: FleetWorkerPool,
                            sched: FleetScheduler, counts: np.ndarray,
                            i0: int, dispatch_every: int, obs) -> None:
    """One chunk of the unsharded NumPy reference loop: serve ticks
    ``[i0, i0 + counts.shape[0])`` with per-tick arrival counts
    ``counts`` ((k, W) int64). Identical per-tick cadence to
    :func:`run_fleet`'s host loop — admission takes the count row
    directly (``submit`` reduces workload ids to exactly this bincount,
    and an all-zero row is the same no-op as an empty arrival slice),
    and the tick index stays GLOBAL so harvest columns, dispatch/evict
    phase, and shed deadlines are chunk-invariant."""
    counts = np.asarray(counts, dtype=np.int64)
    dt = pool.dt
    for j in range(counts.shape[0]):
        i = i0 + j
        t = i * dt
        if obs is not None:
            obs.host_begin(pool.state, sched.state)
        c = counts[j]
        if c.any():
            sched._store(_sched.admit(sched.params, sched._ss(), c,
                                      float(t), np))
        tick = i % dispatch_every == 0
        if tick:
            sched.dispatch(t, i)
            if obs is not None:
                obs.host_after_dispatch(pool.state)
        pool.step(i)
        if obs is not None:
            obs.host_before_evict(pool.state)
        sched.collect(t, evict=tick)
        if obs is not None:
            obs.host_end(i, tick, pool.state, sched.state)


class StreamClient:
    """Live request generator: a background producer thread feeds
    per-tick ``(W,)`` arrival-count rows into a bounded queue, and the
    serve loop's :meth:`take` blocks for the next chunk — the MaxText
    offline-inference pattern of a host-side arrival queue decoupling
    request generation from the compiled serve launches.

    Rows come from the same deterministic ``RequestStream`` counts
    matrix the offline path consumes, in order, so a streamed run is
    row-for-row identical to the offline arrivals — that determinism is
    what lets the differential suite pin chunked == whole-trace
    bit-equality through the live client too.
    """

    def __init__(self, stream: RequestStream, n_workloads: int,
                 n_steps: int | None = None, max_buffer: int = 4096):
        import queue
        import threading
        counts = stream.counts_matrix(n_workloads)
        if n_steps is not None:
            counts = counts[:n_steps]
        self.n_steps = counts.shape[0]
        self.n_workloads = int(n_workloads)
        self._q = queue.Queue(maxsize=max_buffer)
        self._thread = threading.Thread(
            target=self._feed, args=(counts,), daemon=True)
        self._thread.start()

    def _feed(self, counts: np.ndarray) -> None:
        for row in counts:
            self._q.put(row)

    def take(self, k: int) -> np.ndarray:
        """Block until the next ``k`` arrival rows are available and
        return them stacked as a (k, W) int64 matrix."""
        return np.stack([self._q.get() for _ in range(k)]).astype(
            np.int64)


_CHUNK_COUNTERS = ("submitted", "completed", "shed", "rejected",
                   "lost", "evicted", "requeued", "lat_sum")


def _chunk_snapshot(state) -> dict:
    v = _sched.merged_sched_view(state)
    snap = {f: int(getattr(v, f)) for f in _CHUNK_COUNTERS
            if f != "lat_sum"}
    snap["lat_sum"] = float(np.asarray(v.lat_sum))
    snap["lat_hist"] = np.asarray(v.lat_hist).copy()
    return snap


def run_fleet_stream(pool: FleetWorkerPool, sched: FleetScheduler,
                     source, n_steps: int, *, chunk_ticks: int,
                     dispatch_every: int = 10, refit_every: int = 0,
                     obs=None, slo_p95_s: float = 0.0) -> dict:
    """Streaming online serve: the chunked steady-state loop.

    Scans a fixed window of ``chunk_ticks`` ticks per launch, carrying
    the full (FleetState, SchedState, TeleState) across chunk
    boundaries, and injects host-submitted arrivals between chunks —
    ``source`` is either a live :class:`StreamClient` (its ``take``
    blocks on the producer thread) or an offline :class:`RequestStream`
    (rows sliced from the counts matrix). The final, possibly shorter,
    chunk covers the trace remainder, so ``chunk_ticks`` need not
    divide ``n_steps``.

    With a JAX pool each chunk is one fused ``run_serve`` launch
    (``i0 = pool.steps_done`` keeps harvest columns and obs indices
    global); equal-size chunks reuse a single compiled function, and a
    causal refit between chunks swaps only the runtime ``FC_*``
    tables — no re-trace. With a NumPy pool the chunk runs through the
    per-tick reference loop (sharded pools through the
    :class:`_ShardedHostServe` window driver). When the arrival rows
    are identical and ``refit_every`` is 0, the chunked run is
    **bit-exact** with the whole-trace launch on every summary field —
    the differential suite in tests/test_streaming.py pins this.

    ``refit_every`` (ticks; 0 = off) triggers
    :meth:`FleetScheduler.refit_forecast` at the first chunk boundary
    at least that many ticks after the previous refit — the causal,
    prefix-only re-estimation of the forecaster tables from the harvest
    actually observed so far.

    The returned summary carries a ``"stream"`` block: per-chunk
    latency/throughput records (p50/p95/p99 from the latency histogram
    delta), refit count, and — when ``slo_p95_s`` > 0 — a per-chunk
    p95 SLO verdict and total violation count. Wall-clock fields are
    nondeterministic; equality checks strip the block.
    """
    import time
    if chunk_ticks <= 0:
        raise ValueError(f"chunk_ticks={chunk_ticks} must be positive")
    dt = pool.dt
    sp = sched.params
    is_jax = getattr(pool, "backend", "numpy") == "jax"
    sharded = sched.params.shards > 1
    host_serve = None
    if not is_jax and sharded:
        host_serve = _ShardedHostServe(pool, sched, dispatch_every, obs)
    counts_all = None
    if not hasattr(source, "take"):
        counts_all = source.counts_matrix(sp.W)[:n_steps]
    chunks = []
    done = 0
    last_refit = 0
    refits = 0
    violations = 0
    while done < n_steps:
        k = min(int(chunk_ticks), n_steps - done)
        counts = (source.take(k) if counts_all is None
                  else counts_all[done:done + k])
        before = _chunk_snapshot(sched.state)
        t0 = time.perf_counter()
        if is_jax:
            pool.run_serve(sched, counts, dispatch_every=dispatch_every,
                           obs=obs)
        elif sharded:
            host_serve.window(counts, done)
        else:
            _run_fleet_numpy_window(pool, sched, counts, done,
                                    dispatch_every, obs)
        wall = time.perf_counter() - t0
        after = _chunk_snapshot(sched.state)
        hist = after["lat_hist"] - before["lat_hist"]
        completed = after["completed"] - before["completed"]
        lat_sum = after["lat_sum"] - before["lat_sum"]
        rec = {"tick0": done, "ticks": k,
               "wall_s": wall,
               "throughput_rps": completed / (k * dt),
               "mean_latency_s": (lat_sum / completed
                                  if completed else 0.0),
               "p50_s": _hist_percentile(hist, sp.lat_max_s, 0.50),
               "p95_s": _hist_percentile(hist, sp.lat_max_s, 0.95),
               "p99_s": _hist_percentile(hist, sp.lat_max_s, 0.99)}
        for f in _CHUNK_COUNTERS:
            if f != "lat_sum":
                rec[f] = after[f] - before[f]
        if slo_p95_s > 0.0:
            rec["slo_ok"] = bool(rec["p95_s"] <= slo_p95_s)
            violations += not rec["slo_ok"]
        chunks.append(rec)
        done += k
        if (refit_every and done < n_steps
                and done - last_refit >= refit_every):
            if sched.refit_forecast(done):
                refits += 1
            last_refit = done
    summary = sched.summary(n_steps * dt)
    summary["stream"] = {"chunk_ticks": int(chunk_ticks),
                         "refit_every": int(refit_every),
                         "refits": refits,
                         "n_chunks": len(chunks),
                         "chunks": chunks}
    if slo_p95_s > 0.0:
        summary["stream"]["slo_p95_s"] = float(slo_p95_s)
        summary["stream"]["slo_violations"] = violations
    return summary
