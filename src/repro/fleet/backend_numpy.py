"""NumPy reference backend: the per-tick fleet transition, in place.

This is the original ``FleetWorkerPool.step`` lifted out of the class into
pure struct-of-arrays functions over ``(FleetParams, FleetState)`` — the
arithmetic mirrors the scalar ``core.intermittent`` executor expression-
for-expression (pinned at N=1 by tests/test_fleet.py), and is in turn the
reference the JAX scan backend is pinned against. Python-side outputs
(``results`` per-worker EmittedResult lists in local mode, ``events``
tuples in dispatch mode) are appended to caller-owned lists; the JAX
backend replaces them with fixed-capacity arrays.

Event tuples pushed to ``events`` in dispatch mode:
  ("emit", t, worker, ticket, units_done, req_units, batch)
  ("lost", t, worker, ticket)   -- brown-out or failed emission

Under the persistence plane (``params.persist`` != "none" — see
docs/persistence_plane.md) a brown-out mid-request is a power-down, not
a loss: the worker keeps ``has_work``, pays a FRAM restore on its next
productive wake, and re-executes to *exact* completion. No "lost"
events are emitted in the exact disciplines.
"""
from __future__ import annotations

import numpy as np

from repro.core.energy import (capacitor_draw, capacitor_harvest,
                               capacitor_usable_energy)
from repro.core.intermittent import EmittedResult
from repro.core.policies import SKIP
from repro.fleet.state import STATE_FIELDS, FleetParams, FleetState

EMIT = "emit"
LOST = "lost"


def usable_energy(p: FleetParams, s: FleetState) -> np.ndarray:
    """Per-worker usable joules — the budget the host scheduler reads.

    Quantized states (``p.quantum_j`` set) hold energy quanta in ``v``;
    the quanta -> joules conversion here is the exact float64 expression
    the fused jax serve build uses, so dispatch decisions agree
    bit-for-bit across backends in both precisions."""
    if p.quantum_j is not None:
        from repro.fleet.qtick import quantize_fleet_cached
        qp = quantize_fleet_cached(p)
        from repro.core.energy import capacitor_usable_q
        return (capacitor_usable_q(s.v, qp.E_OFF, np)
                .astype(np.float64) * p.quantum_j)
    return capacitor_usable_energy(s.v, capacitance_f=p.C, v_off=p.v_off)


def _draw_at(p: FleetParams, s: FleetState, idx: np.ndarray,
             amount: np.ndarray) -> np.ndarray:
    """Draw ``amount`` at workers ``idx``; brown-outs get v_off and False,
    exactly like ``Capacitor.draw``."""
    new_v, ok = capacitor_draw(s.v[idx], amount, capacitance_f=p.C[idx],
                               v_off=p.v_off)
    s.v[idx] = new_v
    return ok


def tick(p: FleetParams, s: FleetState, i: int,
         results: list[list[EmittedResult]] | None,
         events: list[tuple] | None) -> None:
    """Advance all N workers by one dt (trace index ``i``)."""
    if p.quantum_j is not None:
        return _tick_quantized(p, s, i, events)
    t = i * p.dt
    dt = p.dt

    # 1. harvest (mirrors Capacitor.harvest)
    if p.phase is None:
        pw = p.power[p.trace_index, i % p.T]
    else:
        pw = p.power[p.trace_index, (i + p.phase) % p.T]
    s.e_harvest += p.eff * pw * dt
    s.v = capacitor_harvest(s.v, pw, dt, capacitance_f=p.C,
                            booster_eff=p.eff, v_max=p.v_max)

    # 2. turn on at v_on
    waking = ~s.on & (s.v >= p.v_on)
    s.on |= waking
    s.cycles += waking
    active = s.on.copy()

    # workers holding work from a previous tick progress it; workers
    # acquiring this tick spend the whole dt on acquisition (scalar
    # semantics: the acquisition branch ends the step)
    working = active & s.has_work
    idle = active & ~s.has_work

    # persistence plane: a worker that powered down mid-request pays the
    # FRAM restore read before it may progress again (the restore
    # consumes its tick)
    if p.persist != "none":
        working = _restore(p, s, working)

    # 3. acquisition
    if p.mode == "local":
        _acquire_local(p, s, idle, t)
    else:
        _acquire_dispatch(p, s, idle, t, events)

    # 4. progress in-flight work by one dt of active execution
    emit_now = np.zeros(p.n, dtype=bool)
    if working.any():
        emit_now = _progress(p, s, working, t, events)

    # 5. emission (BLE packet / host transfer)
    finish = (working & s.has_work & s.on
              & ((s.w_units_done >= s.w_target) | emit_now))
    if finish.any():
        _emit(p, s, np.nonzero(finish)[0], t, results, events)


def _tick_quantized(p: FleetParams, s: FleetState, i: int,
                    events: list[tuple] | None) -> None:
    """Quantized (int32 quanta) dispatch tick: the NumPy reference
    driver for the serve-tick megakernel path. Runs the exact
    xp-generic integer expressions of ``repro.fleet.qtick`` (the same
    function body the ``kernel="q32"`` scan traces) and decodes the
    fixed-capacity event log back into the host tuple protocol."""
    from repro.fleet import qtick as Q
    qp = Q.quantize_fleet_cached(p)
    qh = Q.harvest_row(p, qp, p.trace_index, p.phase, i, np)
    st = tuple(getattr(s, f) for f in STATE_FIELDS)
    z = lambda: np.zeros(p.n, dtype=np.int32)  # noqa: E731
    ev = (z(), z(), z(), z())
    st, ev = Q.tick_q(p, qp, st, ev, qh, i, np, Q.np_while)
    for f, x in zip(STATE_FIELDS, st):
        setattr(s, f, x)
    if events is None:
        return
    t = i * p.dt
    evc, _, evtk, evu = ev
    for w in np.nonzero(evc != Q.EV_NONE)[0]:
        w = int(w)
        if evc[w] == Q.EV_EMIT:
            events.append((EMIT, t, w, int(evtk[w]), int(evu[w]),
                           int(s.w_tile[w]), int(s.w_batch[w])))
        else:
            events.append((LOST, t, w, int(evtk[w])))


def _acquire_local(p: FleetParams, s: FleetState, idle: np.ndarray,
                   t: float) -> None:
    due = idle & (t >= s.next_sample_t)
    if not due.any():
        return
    d_idx = np.nonzero(due)[0]
    delta = t - s.next_sample_t[d_idx]
    k = delta // p.P
    s.sample_counter[d_idx] += k.astype(np.int64) + 1
    s.next_sample_t[d_idx] += p.P * (k + 1.0)
    # decide BEFORE spending anything (SMART skips the whole round)
    us = usable_energy(p, s)[d_idx]
    init, refine = p.policy.decide_batch(us, p.tables[0], p.acc)
    skip = init == SKIP
    s.skipped[d_idx[skip]] += 1
    go = d_idx[~skip]
    if go.size == 0:
        return
    fixed = p.FIX[0]
    ok = _draw_at(p, s, go, np.minimum(fixed, us[~skip]))
    s.on[go[~ok]] = False
    succ = go[ok]
    s.e_work[succ] += fixed
    s.acquired[succ] += 1
    s.has_work[succ] = True
    s.w_ticket[succ] = s.sample_counter[succ] - 1
    s.w_t_acq[succ] = t
    s.w_cycle_acq[succ] = s.cycles[succ]
    s.w_units_done[succ] = 0
    s.w_left[succ] = 0.0
    s.w_target[succ] = np.where(refine, p.NU[0], init)[~skip][ok]
    s.w_tile[succ] = 0
    s.w_wl[succ] = 0
    s.w_batch[succ] = 1


def _restore(p: FleetParams, s: FleetState, working: np.ndarray
             ) -> np.ndarray:
    """Persistence-plane restore (persist != "none"): pay the FRAM read
    that reloads the progress image (ckpt) or log header (undolog).
    Returns ``working`` minus the restoring lanes — a restore consumes
    the worker's tick before it can progress again."""
    rest = working & s.need_restore
    if not rest.any():
        return working
    r_idx = np.nonzero(rest)[0]
    rj = p.REST_J[s.w_wl[r_idx]]
    ok = _draw_at(p, s, r_idx, rj)
    # not enough banked for the read yet: recharge more (defensive — a
    # freshly woken worker holds a full cycle of charge)
    s.on[r_idx[~ok]] = False
    succ = r_idx[ok]
    s.need_restore[succ] = False
    s.restores[succ] += 1
    s.e_persist[succ] += rj[ok]
    if p.persist == "ckpt":
        # Mementos semantics: rewind to the checkpointed unit counter;
        # progress past the last image is lost and re-executes
        s.w_units_done[succ] = s.ck_units[succ]
    # either way the partial unit in flight restarts idempotently
    s.w_left[succ] = 0.0
    return working & ~rest


def _acquire_dispatch(p: FleetParams, s: FleetState, idle: np.ndarray,
                      t: float, events: list[tuple]) -> None:
    due = idle & s.p_pending
    if not due.any():
        return
    d_idx = np.nonzero(due)[0]
    wl = s.p_wl[d_idx]
    us = usable_energy(p, s)[d_idx]
    fixed = p.FIX[wl]
    ok = _draw_at(p, s, d_idx, np.minimum(fixed, us))
    fail = d_idx[~ok]
    s.on[fail] = False
    if p.persist == "none":
        s.p_pending[d_idx] = False
        for w in fail:
            events.append((LOST, t, int(w), int(s.p_ticket[w])))
    else:
        # exact disciplines never drop an accepted request: a failed
        # acquisition keeps the assignment pending across the recharge
        s.p_pending[d_idx[ok]] = False
    succ = d_idx[ok]
    if succ.size == 0:
        return
    if p.persist != "none":
        # fresh request: clear any stale persistence carried from an
        # evicted or completed predecessor
        s.need_restore[succ] = False
        s.ck_units[succ] = 0
    s.e_work[succ] += fixed[ok]
    s.acquired[succ] += 1
    s.has_work[succ] = True
    s.w_ticket[succ] = s.p_ticket[succ]
    s.w_t_acq[succ] = t
    s.w_cycle_acq[succ] = s.cycles[succ]
    s.w_units_done[succ] = 0
    s.w_left[succ] = 0.0
    s.w_tile[succ] = s.p_units[succ]
    s.w_batch[succ] = s.p_batch[succ]
    s.w_target[succ] = s.p_units[succ] * s.p_batch[succ]
    s.w_wl[succ] = s.p_wl[succ]


def _progress(p: FleetParams, s: FleetState, working: np.ndarray, t: float,
              events: list[tuple] | None) -> np.ndarray:
    """One dt of active execution for every working device; returns the
    emit_now mask (budget died at a unit boundary -> emit what we have)."""
    emit_now = np.zeros(p.n, dtype=bool)
    e_step = np.zeros(p.n)
    e_step[working] = p.active_power_w[working] * p.dt
    # scalar loop guard: `while e_step > 0 and units_done < target` —
    # a target-0 work item skips straight to emission
    run = working & (s.w_units_done < s.w_target)
    while True:
        r_idx = np.nonzero(run)[0]
        if r_idx.size == 0:
            break
        # unit boundary: start the next unit only if unit + reserve are
        # affordable now. Approximate: reserve = the BLE emit packet and
        # "cant" emits the partial result. Exact (persist != "none"):
        # reserve additionally covers the checkpoint image / unit commit
        # write, and "cant" is a forced power-down — the request is
        # persisted, never truncated.
        starting = s.w_left[r_idx] <= 0
        if starting.any():
            s_idx = r_idx[starting]
            ud = s.w_units_done[s_idx]
            tile = s.w_tile[s_idx]
            gidx = np.where(tile > 0, ud % np.maximum(tile, 1), ud)
            nc = p.UC[s.w_wl[s_idx], gidx]
            us = usable_energy(p, s)[s_idx]
            if p.persist == "none":
                cant = us < nc + p.EMITC[s.w_wl[s_idx]]
                emit_now[s_idx[cant]] = True
            else:
                rsv = p.CKPT_J if p.persist == "ckpt" else p.COMMIT_J
                cant = us < (nc + rsv[s.w_wl[s_idx]]
                             + p.EMITC[s.w_wl[s_idx]])
                if p.persist == "ckpt":
                    # the voltage trigger fired: serialize dirty
                    # progress to FRAM before dying (the reserve at the
                    # previous boundary guarantees this write is funded)
                    dirty = s_idx[cant & (s.w_units_done[s_idx]
                                          != s.ck_units[s_idx])]
                    if dirty.size:
                        cj = p.CKPT_J[s.w_wl[dirty]]
                        okc = _draw_at(p, s, dirty, cj)
                        wrote = dirty[okc]
                        s.ck_units[wrote] = s.w_units_done[wrote]
                        s.persists[wrote] += 1
                        s.e_persist[wrote] += cj[okc]
                down = s_idx[cant]
                s.on[down] = False
                s.need_restore[down] = True
            run[s_idx[cant]] = False
            go = s_idx[~cant]
            s.w_left[go] = nc[~cant]
            r_idx = np.nonzero(run)[0]
            if r_idx.size == 0:
                break
        take = np.minimum(e_step[r_idx], s.w_left[r_idx])
        ok = _draw_at(p, s, r_idx, take)
        fail = r_idx[~ok]
        if fail.size:
            s.on[fail] = False
            run[fail] = False
            if p.persist == "none":
                # power failure mid-work: volatile by design; work lost
                s.has_work[fail] = False
                if p.mode == "dispatch":
                    for w in fail:
                        events.append(
                            (LOST, t, int(w), int(s.w_ticket[w])))
            else:
                # the persisted request survives; restore re-runs the
                # partial unit
                s.need_restore[fail] = True
        succ = r_idx[ok]
        tk = take[ok]
        s.e_work[succ] += tk
        s.w_left[succ] -= tk
        e_step[succ] -= tk
        fin = succ[s.w_left[succ] <= 1e-18]
        halted = np.empty(0, dtype=np.int64)
        if p.persist == "undolog" and fin.size:
            # Alpaca task commit: the completed unit's undo-buffer write
            # makes w_units_done durable (funded by the boundary reserve)
            cj = p.COMMIT_J[s.w_wl[fin]]
            okc = _draw_at(p, s, fin, cj)
            halted = fin[~okc]
            s.on[halted] = False
            s.need_restore[halted] = True
            fin = fin[okc]
            s.persists[fin] += 1
            s.e_persist[fin] += cj[okc]
        s.w_units_done[fin] += 1
        s.w_left[fin] = 0.0
        run[succ] = ((e_step[succ] > 0)
                     & (s.w_units_done[succ] < s.w_target[succ]))
        run[halted] = False
    return emit_now


def _emit(p: FleetParams, s: FleetState, f_idx: np.ndarray, t: float,
          results: list[list[EmittedResult]] | None,
          events: list[tuple] | None) -> None:
    ec = p.EMITC[s.w_wl[f_idx]]
    ok = _draw_at(p, s, f_idx, ec)
    fail = f_idx[~ok]
    s.on[fail] = False
    if p.persist == "none":
        s.has_work[fail] = False  # volatile: failed emission loses it
        if p.mode == "dispatch":
            for w in fail:
                events.append((LOST, t, int(w), int(s.w_ticket[w])))
    else:
        # persisted work retries the emission after the next restore
        s.need_restore[fail] = True
    succ = f_idx[ok]
    s.e_work[succ] += ec[ok]
    s.has_work[succ] = False
    s.emit_count[succ] += 1
    s.emit_units_sum[succ] += s.w_units_done[succ]
    if p.mode == "local":
        s.emit_acc_sum[succ] += p.acc[np.minimum(s.w_units_done[succ],
                                                 p.NU[0])]
    for w in succ:  # emissions are rare relative to ticks
        w = int(w)
        if p.mode == "local":
            results[w].append(EmittedResult(
                int(s.w_ticket[w]), int(s.w_units_done[w]),
                float(s.w_t_acq[w]), t,
                int(s.cycles[w] - s.w_cycle_acq[w])))
        else:
            events.append(
                (EMIT, t, w, int(s.w_ticket[w]),
                 int(s.w_units_done[w]), int(s.w_tile[w]),
                 int(s.w_batch[w])))
