"""JAX fleet backend: the whole trace as one ``lax.scan`` device launch.

The per-tick transition of ``repro.fleet.backend_numpy`` re-expressed as a
pure function over the struct-of-arrays ``FleetState`` — harvest, wake,
acquire, progress, emit are the *same float64 expressions* (shared via the
stateless capacitor helpers in ``core.energy`` and the ``xp=jnp`` policy
closed forms), evaluated as a batched whole-array step: each masked
``jnp.where`` lane is exactly what a ``jax.vmap`` of the scalar device
step would compute, with the data-dependent unit loop as a fleet-wide
``lax.while_loop`` that retires lanes as their dt budget drains. A run of
``n_ticks`` is a single ``lax.scan`` over that step — 100k+ workers fit
one accelerator launch instead of 100k Python-object updates per tick.

Numerical contract: under ``jax.experimental.enable_x64`` every operation
runs in IEEE double like the NumPy reference. XLA:CPU contracts
multiply-add chains into FMAs (not disableable via flags as of jax
0.4.37), so capacitor *voltages* can drift from NumPy by ~1 ulp; every
discrete outcome — emitted / skipped / acquired / power-cycle counts,
drawn energies, emission times — agrees exactly on shared traces because
threshold comparisons sit ulps away from the knife edge with probability
~1e-13 per event (tests/test_fleet_backends.py pins count equality).

Events (dispatch mode) are materialized as fixed-capacity (N,) arrays —
code / time / ticket / units per worker — instead of Python tuple lists.
Capacity one-per-worker-per-macro-step is an invariant, not a truncation:
a worker's assignment can terminate (emit or loss) at most once per
tick, and new assignments only arrive between device steps.

``run_serve`` goes further: the array-native control plane
(``repro.fleet.sched``) is traced *into* the scan — admission and event
collection every tick, shed/dispatch/evict under a ``lax.cond`` at the
dispatch cadence — so an entire serve trace (workers AND scheduler) is a
single compiled launch; events are consumed by the in-scan collect the
same tick they occur and never reach the host at all.

Optionally the harvest stage runs through the Pallas capacitor-bank
kernel (``repro.kernels.fleet_step``) — the TPU fast path; interpret mode
keeps it testable on CPU-only environments.

``kernel`` selects the device-tick numerics/implementation:

- ``"xla"`` (default) — the float64 jnp expression chain above;
- ``"q32"`` — the int32 quantized tick (``repro.fleet.qtick``) traced
  as pure XLA: same scan, integer energy quanta, no sqrt;
- ``"pallas"`` — the same quantized tick fused into one VMEM-resident
  Pallas pass per tick (``repro.kernels.serve_tick``), compiled on TPU
  and interpret-mode (still pure XLA, still bit-exact vs ``q32``) on
  CPU. Quantized kernels are dispatch-mode only and need a quantized
  ``FleetState`` (``init_state(n, quantized=True)``) plus
  ``FleetParams.quantum_j`` — ``FleetWorkerPool(kernel=...)`` wires all
  three.
"""
from __future__ import annotations

import collections
import copy
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.energy import (capacitor_draw, capacitor_harvest,
                               capacitor_usable_energy,
                               capacitor_usable_q)
from repro.fleet.state import (STATE_FIELDS, FleetParams, FleetState,
                               SchedParams, SchedState,
                               sched_state_as_tuple,
                               sched_state_from_tuple, state_as_tuple,
                               state_from_tuple)

_S = collections.namedtuple("_S", STATE_FIELDS)

# event codes in the fixed-capacity array log
EV_NONE, EV_EMIT, EV_LOST = 0, 1, 2


class JaxFleetBackend:
    """Compiled scan runner for one ``FleetParams`` configuration."""

    def __init__(self, params: FleetParams, *, use_pallas: bool = False,
                 kernel: str = "xla", fleet_placement: str = "auto"):
        self.p = params
        self.use_pallas = use_pallas
        self.kernel = kernel
        self.fleet_placement = fleet_placement
        self.interpret = jax.default_backend() != "tpu"
        if kernel not in ("xla", "q32", "pallas"):
            raise ValueError(f"unknown kernel {kernel!r}")
        if fleet_placement not in ("auto", "mesh", "single"):
            raise ValueError(
                f"unknown fleet_placement {fleet_placement!r} "
                "(auto | mesh | single)")
        if kernel != "xla":
            if params.mode != "dispatch":
                raise ValueError(
                    "quantized kernels (q32/pallas) implement the serve "
                    "tick only; local mode stays float64")
            if params.quantum_j is None:
                raise ValueError(
                    "quantized kernels need FleetParams.quantum_j (use "
                    "FleetWorkerPool(kernel=...) to wire params + state)")
        if kernel == "pallas" and params.persist != "none":
            raise ValueError(
                "--persist ckpt/undolog supports the xla and q32 "
                "kernels; the Pallas serve megakernel implements the "
                "approximate tick only")
        if params.mode == "local":
            # surface non-traceable policies at build time, not mid-scan:
            # the base-class decide_batch is the NumPy-only loop fallback,
            # and an override without an `xp` parameter is a pre-xp custom
            # policy that would die with an opaque error inside tracing
            import inspect

            from repro.core.policies import Policy
            impl = type(params.policy).decide_batch
            if (impl is Policy.decide_batch
                    or "xp" not in inspect.signature(impl).parameters):
                raise TypeError(
                    f"policy {type(params.policy).__name__}'s decide_batch "
                    "cannot run under jax tracing; the jax backend needs "
                    "an xp-aware closed form (see core.policies)")
        with enable_x64():
            self.power = jnp.asarray(params.power)
            self.trace_index = jnp.asarray(params.trace_index)
            self.phase = (None if params.phase is None
                          else jnp.asarray(params.phase))
            self.C = jnp.asarray(params.C)
            self.v_max = jnp.asarray(params.v_max)
            self.UC = jnp.asarray(params.UC)
            self.FIX = jnp.asarray(params.FIX)
            self.EMITC = jnp.asarray(params.EMITC)
            self.NU = jnp.asarray(params.NU)
            self.AP = jnp.asarray(params.active_power_w)
            zw = np.zeros(np.asarray(params.FIX).shape[0])
            self.CKPT_J = jnp.asarray(params.CKPT_J
                                      if params.CKPT_J is not None else zw)
            self.REST_J = jnp.asarray(params.REST_J
                                      if params.REST_J is not None else zw)
            self.COMMIT_J = jnp.asarray(
                params.COMMIT_J if params.COMMIT_J is not None else zw)
            self.ACC = (None if params.acc is None
                        else jnp.asarray(np.asarray(params.acc,
                                                    dtype=np.float64)))
            if kernel != "xla":
                from repro.fleet import qtick as Q
                qp_np = Q.quantize_fleet_cached(params)
                self._qp = Q.convert_arrays(qp_np, jnp.asarray)
                if kernel == "pallas":
                    from repro.kernels.serve_tick import replicate_table
                    pad8 = lambda k: -(-k // 8) * 8  # noqa: E731
                    w, u = qp_np.UCQ.shape
                    self._k_tables = dict(
                        uc=replicate_table(qp_np.UCQ.reshape(-1),
                                           pad8(w * u)),
                        fix=replicate_table(qp_np.FIXQ, pad8(w)),
                        emitc=replicate_table(qp_np.EMITCQ, pad8(w)))
        self._compiled: dict[int, callable] = {}
        self._serve_compiled: dict[tuple, callable] = {}
        self._serve_sp: SchedParams | None = None
        self._pow_cs = None  # lazy shared power prefix-sum (obs)

    # -- public API ----------------------------------------------------------

    def run(self, state: FleetState, i0: int,
            n_ticks: int) -> tuple[FleetState, list[tuple]]:
        """Advance ``n_ticks`` from trace index ``i0``; returns the updated
        host-side state and decoded dispatch events (empty in local mode).
        """
        p = self.p
        with enable_x64():
            st = tuple(jnp.asarray(x) for x in state_as_tuple(state))
            n = p.n
            if self.kernel == "xla":
                ev0 = (jnp.zeros(n, jnp.int64), jnp.zeros(n, jnp.float64),
                       jnp.zeros(n, jnp.int64), jnp.zeros(n, jnp.int64))
            else:  # quantized log: int32 codes, integer tick times
                ev0 = tuple(jnp.zeros(n, jnp.int32) for _ in range(4))
            fn = self._compiled.get(n_ticks)
            if fn is None:
                fn = self._build(n_ticks)
                self._compiled[n_ticks] = fn
            st_out, ev_out = fn(st, ev0, jnp.asarray(i0, jnp.int64))
            # np.array (copy): the host state must stay writable for the
            # scheduler's assign/evict mutations between macro-steps
            st_out = tuple(np.array(x) for x in st_out)
            ev_out = tuple(np.asarray(x) for x in ev_out)
        new_state = state_from_tuple(st_out)
        events = (self._decode_events(new_state, ev_out)
                  if p.mode == "dispatch" else [])
        return new_state, events

    # -- event decoding ------------------------------------------------------

    def _decode_events(self, s: FleetState, ev: tuple) -> list[tuple]:
        from repro.fleet.backend_numpy import EMIT, LOST
        code, ev_t, ev_ticket, ev_units = ev
        # quantized logs stamp integer tick indices, not seconds
        scale = 1.0 if self.kernel == "xla" else self.p.dt
        hit = np.nonzero(code != EV_NONE)[0]
        out: list[tuple] = []
        for w in hit[np.lexsort((hit, ev_t[hit]))]:  # temporal order
            w = int(w)
            if code[w] == EV_EMIT:
                out.append((EMIT, float(ev_t[w]) * scale, w,
                            int(ev_ticket[w]),
                            int(ev_units[w]), int(s.w_tile[w]),
                            int(s.w_batch[w])))
            else:
                out.append((LOST, float(ev_t[w]) * scale, w,
                            int(ev_ticket[w])))
        return out

    # -- compiled scan -------------------------------------------------------

    def _pick_tick(self):
        """The per-tick transition for this backend's kernel mode."""
        if self.kernel == "q32":
            return self._tick_q
        if self.kernel == "pallas":
            return self._tick_pallas
        return self._tick

    def _build(self, n_ticks: int):
        tick = self._pick_tick()

        def scan_fn(st, ev, i0):
            def body(carry, j):
                return tick(carry[0], carry[1], i0 + j), None

            (st, ev), _ = lax.scan(body, (st, ev),
                                   jnp.arange(n_ticks, dtype=jnp.int64))
            return st, ev

        return jax.jit(scan_fn)

    # -- fused serve scan (workers + scheduler in one launch) ---------------

    def run_serve(self, state: FleetState, sp: SchedParams,
                  sched_state: SchedState, arrivals: np.ndarray, *,
                  i0: int = 0, dispatch_every: int = 10, obs=None
                  ) -> tuple[FleetState, SchedState]:
        """The whole serve trace — device physics AND the array-native
        control plane (``repro.fleet.sched``) — as one ``lax.scan``: the
        per-tick arrival counts are the scan input, admission/collection
        run every tick, the shed/dispatch/evict passes fire under a
        ``lax.cond`` at the dispatch cadence, and only the two final
        states come back to the host. No per-macro-step transfers.

        ``obs`` (a ``repro.obs.FleetObs``) threads the telemetry /
        event-ring arrays through the scan carry and writes them back
        here — the serve expressions themselves are untouched (the
        zero-perturbation contract), and with ``obs=None`` the compiled
        program is byte-identical to the uninstrumented build."""
        if self.p.mode != "dispatch":
            raise ValueError("run_serve needs a dispatch-mode fleet")
        if obs is not None and self.kernel != "xla":
            raise ValueError(
                "the observability plane reads float64 device state; "
                "quantized kernels (q32/pallas) run uninstrumented")
        if sp.shards > 1:
            return self._run_serve_sharded(
                state, sp, sched_state, arrivals, i0=i0,
                dispatch_every=int(dispatch_every), obs=obs)
        from repro.fleet import sched as S
        arrivals = np.asarray(arrivals, dtype=np.int64)
        n_ticks = arrivals.shape[0]
        op = None if obs is None else obs.op
        key = (n_ticks, int(dispatch_every), op)
        # a causal refit only rebinds the FC_* forecast tables, which
        # enter the compiled launch as runtime arguments — every other
        # change to the control-plane config forces a re-trace
        if not S.sched_params_compatible(self._serve_sp, sp):
            self._serve_compiled = {}
        self._serve_sp = sp
        with enable_x64():
            fs = tuple(jnp.asarray(x) for x in state_as_tuple(state))
            ss = tuple(jnp.asarray(x)
                       for x in sched_state_as_tuple(sched_state))
            pw = {f: jnp.asarray(getattr(sp, f)) for f in S.FC_FIELDS}
            fn = self._serve_compiled.get(key)
            if fn is None:
                fn = self._build_serve(sp, n_ticks, int(dispatch_every),
                                       op=op)
                self._serve_compiled[key] = fn
            if op is None:
                fs, ss = fn(fs, ss, pw, jnp.asarray(arrivals),
                            jnp.asarray(i0, jnp.int64))
            else:
                from repro.obs.state import (ring_as_tuple,
                                             ring_from_tuple,
                                             tele_as_tuple,
                                             tele_from_tuple)
                tele = tuple(jnp.asarray(x)
                             for x in tele_as_tuple(obs.tele))
                ring = (None if obs.ring is None else
                        tuple(jnp.asarray(x)
                              for x in ring_as_tuple(obs.ring)))
                fs, ss, tele, ring = fn(fs, ss, tele, ring, pw,
                                        jnp.asarray(arrivals),
                                        jnp.asarray(i0, jnp.int64))
                obs.tele = tele_from_tuple(
                    tuple(np.asarray(x) for x in tele))
                if ring is not None:
                    obs.ring = ring_from_tuple(
                        tuple(np.asarray(x) for x in ring))
            fs = tuple(np.array(x) for x in fs)
            ss = tuple(np.asarray(x) for x in ss)
        return state_from_tuple(fs), sched_state_from_tuple(ss)

    def _power_cumsum(self):
        """Shared (R, T+1) power prefix-sum, computed once in NumPy (so
        the obs forecast-error gathers read values bit-identical to the
        host driver's) and cached on device."""
        if self._pow_cs is None:
            from repro.obs.telemetry import power_cumsum
            with enable_x64():
                self._pow_cs = jnp.asarray(
                    power_cumsum(np.asarray(self.p.power)))
        return self._pow_cs

    def _serve_body(self, view, sp: SchedParams, dispatch_every: int,
                    op=None, obs_cs=None, rebalance=None):
        """The per-tick serve transition as a ``lax.scan`` body closure.

        ``view`` carries the device-resident per-worker constants:
        ``self`` for the single-shard build, or a shard-sliced shallow
        copy under the sharded build — ``_tick``/``_tick_q`` and the
        scheduler passes then read shard-local rows with no code
        changes (replicated tables like the power matrix and cost
        tables stay closure-captured, which ``shard_map`` handles
        bit-identically to ``vmap``). ``rebalance`` (sharded builds
        only) splices the cross-shard work-stealing exchange between
        budget planning and dispatch at the ``sp.rebalance_every``
        cadence."""
        from repro.fleet import sched as S
        if op is not None:
            from repro.obs import telemetry as O
        p = view.p
        n = p.n
        tick = view._pick_tick()
        quant = self.kernel != "xla"

        def body(carry, xs):
            if op is None:
                fs, ss = carry
                i, counts = xs
            else:
                (fs, ss), (tele, ring) = carry
                i, j, counts = xs
            fs0 = _S(*fs)
            ssb = ss  # tick-start snapshot (immutable namedtuple view)
            t = i * p.dt
            ss = S.admit(sp, ss, counts, t, jnp)
            is_tick = (i % dispatch_every) == 0

            def do_dispatch(args):
                fsn, ss = args
                ss = S.shed(sp, ss, t, jnp)
                if quant:
                    # quanta -> joules: the exact float64 expression the
                    # NumPy host driver evaluates (backend agreement)
                    budget_now = (capacitor_usable_q(
                        fsn.v, view._qp.E_OFF, jnp)
                        .astype(jnp.float64) * p.quantum_j)
                else:
                    budget_now = view._usable(fsn.v)
                pw_lags = S.power_lags(view.power, view.trace_index, i,
                                       p.T, sp.fc_order, phase=view.phase,
                                       xp=jnp)
                budget_plan = S.plan_budget(sp, budget_now, pw_lags,
                                            p.eff, jnp)
                if rebalance is not None:
                    ss = lax.cond((i % sp.rebalance_every) == 0,
                                  lambda s: rebalance(s, budget_plan),
                                  lambda s: s, ss)
                dispatchable = fsn.on & ~fsn.has_work & ~fsn.p_pending
                ss, a = S.dispatch(sp, ss, dispatchable, budget_now,
                                   budget_plan, t, jnp)
                cast = ((lambda x: x.astype(jnp.int32)) if quant
                        else (lambda x: x))
                fsn = fsn._replace(
                    p_pending=fsn.p_pending | a.mask,
                    p_wl=jnp.where(a.mask, cast(a.wl), fsn.p_wl),
                    p_units=jnp.where(a.mask, cast(a.units),
                                      fsn.p_units),
                    p_batch=jnp.where(a.mask,
                                      cast(jnp.maximum(a.batch, 1)),
                                      fsn.p_batch),
                    p_t_assigned=jnp.where(
                        a.mask, cast(i) if quant else t,
                        fsn.p_t_assigned))
                return fsn, ss

            fsn, ss = lax.cond(is_tick, do_dispatch, lambda x: x,
                               (fs0, ss))
            if quant:
                ev0 = tuple(jnp.zeros(n, jnp.int32) for _ in range(4))
            else:
                ev0 = (jnp.zeros(n, jnp.int64), jnp.zeros(n, jnp.float64),
                       jnp.zeros(n, jnp.int64), jnp.zeros(n, jnp.int64))
            fs2, ev = tick(tuple(fsn), ev0, i)
            evc, _, _, evu = ev
            ss = S.collect(sp, ss, evc == EV_EMIT, evc == EV_LOST,
                           evu.astype(jnp.int64) if quant else evu,
                           t, jnp)

            def do_evict(args):
                fsn, ss = args
                ss, evm = S.evict(sp, ss, t, jnp)
                return fsn._replace(p_pending=fsn.p_pending & ~evm,
                                    has_work=fsn.has_work & ~evm), ss

            fs2s = _S(*fs2)
            fsn2, ss = lax.cond(is_tick, do_evict, lambda x: x,
                                (fs2s, ss))
            if op is None:
                return (tuple(fsn2), ss), None
            # observability: pure reads of the before/after snapshots
            # above — never feeds back into fs/ss (zero perturbation)
            col = ((i % p.T) if view.phase is None
                   else (i + view.phase) % p.T)
            pw = view.power[view.trace_index, col]
            tele, ring = O.obs_tick(
                op, sp, tele, ring, i=i, j=j, is_tick=is_tick, pw=pw,
                eff=p.eff, dt=p.dt, b=O.dev_snap(fs0),
                sb=O.sched_snap(ssb, jnp),
                assign_mask=fsn.p_pending & ~fs0.p_pending,
                assign_wl=fsn.p_wl,
                evict_mask=((fs2s.p_pending | fs2s.has_work)
                            & ~(fsn2.p_pending | fsn2.has_work)),
                fs=fsn2, ss=ss, power=view.power, cs=obs_cs,
                trace_index=view.trace_index, phase=view.phase, T=p.T,
                xp=jnp)
            return ((tuple(fsn2), ss), (tele, ring)), None

        return body

    def _build_serve(self, sp: SchedParams, n_ticks: int,
                     dispatch_every: int, op=None):
        from repro.fleet import sched as S
        obs_cs = (self._power_cumsum()
                  if op is not None and sp.forecast else None)

        # the FC_* forecast tables arrive as the runtime `pw` dict (the
        # streaming loop's causal refits swap them between chunks without
        # re-tracing); the body closure is built inside the traced
        # function so the scheduler passes read the traced tables, while
        # every other SchedParams field stays a baked constant
        def make_body(pw):
            spt = dataclasses.replace(sp, **pw)
            return self._serve_body(self, spt, dispatch_every, op=op,
                                    obs_cs=obs_cs)

        if op is None:
            def serve_fn(fs, ss, pw, arr, i0):
                xs = (i0 + jnp.arange(n_ticks, dtype=jnp.int64), arr)
                (fs, ss), _ = lax.scan(make_body(pw), (fs, S.SS(*ss)),
                                       xs)
                return fs, tuple(ss)
        else:
            def serve_fn(fs, ss, tele, ring, pw, arr, i0):
                # the obs tick index j is GLOBAL (i0 + local), matching
                # the host drivers' j=i: windowed telemetry and ring
                # timestamps stay chunk-invariant when a serve trace is
                # split across multiple launches
                idx = i0 + jnp.arange(n_ticks, dtype=jnp.int64)
                xs = (idx, idx, arr)
                ((fs, ss), (tele, ring)), _ = lax.scan(
                    make_body(pw), ((fs, S.SS(*ss)), (tele, ring)), xs)
                return fs, tuple(ss), tele, ring

        return jax.jit(serve_fn)

    # -- sharded serve scan (--mesh-fleet K: shard_map over the fleet axis) --

    def _resolve_placement(self, k: int) -> bool:
        """True -> real K-device mesh (``shard_map``), False -> the
        single-device ``vmap`` evaluation of the same K-shard program
        (bit-identical by construction; see docs/sharded_fleet.md)."""
        if self.fleet_placement == "mesh":
            return True
        if self.fleet_placement == "single":
            return False
        return jax.device_count() >= k

    def _run_serve_sharded(self, state: FleetState, sp: SchedParams,
                           sched_state: SchedState, arrivals, *, i0,
                           dispatch_every, obs):
        """``run_serve`` for ``sp.shards == K > 1``: the worker axis is
        split into K contiguous row-shards, each with its own control
        plane (per-shard ring queues, ``max_queue // K`` admission),
        and the whole K-shard program runs as ONE logical launch —
        ``shard_map`` over a ``(fleet,)`` mesh when K devices exist,
        otherwise a ``vmap`` with the same named axis. The two
        placements (and the NumPy host twin) are bit-identical: the
        shard split is semantic, the placement is not."""
        from repro.fleet import sched as S
        p = self.p
        K = sp.shards
        ns = p.n // K
        if self.kernel == "pallas":
            raise ValueError(
                "--mesh-fleet > 1 supports the xla and q32 kernels; the "
                "Pallas serve megakernel tiles a single-device worker "
                "axis (use --kernel q32 for sharded quantized runs)")
        if obs is not None and obs.op.mode != "tele":
            raise ValueError(
                "--obs trace keeps a global per-worker event ring and "
                "is not supported under --mesh-fleet > 1; use --obs "
                "tele (windowed counters reduce exactly across shards)")
        if sp.rebalance_every and (sp.rebalance_every % dispatch_every):
            raise ValueError(
                f"rebalance_every={sp.rebalance_every} ticks must be a "
                f"positive multiple of dispatch_every={dispatch_every}: "
                "the work-stealing exchange runs inside the dispatch "
                "pass")
        use_mesh = self._resolve_placement(K)
        arrivals = np.asarray(arrivals, dtype=np.int64)
        n_ticks = arrivals.shape[0]
        arr = S.split_counts(arrivals, K)  # (K, n_ticks, W)
        op = None if obs is None else obs.op
        key = (n_ticks, int(dispatch_every), op, "sharded", use_mesh)
        # per-worker tables (FC_* included) already enter as runtime
        # inputs via sh["sp"], so a causal refit keeps the trace
        if not S.sched_params_compatible(self._serve_sp, sp):
            self._serve_compiled = {}
        self._serve_sp = sp

        def resh(x):
            a = np.asarray(x)
            return np.ascontiguousarray(a.reshape((K, ns) + a.shape[1:]))

        with enable_x64():
            fs = tuple(jnp.asarray(resh(x))
                       for x in state_as_tuple(state))
            ss = tuple(jnp.asarray(x)  # already stacked (K, ...)
                       for x in sched_state_as_tuple(sched_state))
            sh = {"fs": fs, "ss": ss, "arr": jnp.asarray(arr),
                  "ti": jnp.asarray(resh(p.trace_index)),
                  "ph": jnp.asarray(resh(p.phase)
                                    if p.phase is not None
                                    else np.zeros((K, ns), np.int64)),
                  "C": jnp.asarray(resh(p.C)),
                  "v_max": jnp.asarray(resh(p.v_max)),
                  "AP": jnp.asarray(resh(p.active_power_w)),
                  "sp": {f: jnp.asarray(resh(getattr(sp, f)))
                         for f in S.PER_WORKER_FIELDS}}
            if self.kernel != "xla":
                sh["qp"] = {f: jnp.asarray(resh(getattr(self._qp, f)))
                            for f in ("E_ON", "E_OFF", "E_MAX", "ESTEP")}
            fn = self._serve_compiled.get(key)
            if fn is None:
                fn = self._build_serve_sharded(sp, n_ticks,
                                               int(dispatch_every), op,
                                               use_mesh)
                self._serve_compiled[key] = fn
            out = fn(sh, jnp.asarray(i0, jnp.int64))
            if op is None:
                fs, ss = out
            else:
                fs, ss, tele = out
                from repro.obs.state import tele_as_tuple, tele_from_tuple
                # per-shard windows summed over K: every channel is a
                # scatter-add, so the shard sum IS the global counter
                obs.tele = tele_from_tuple(tuple(
                    np.asarray(o) + np.asarray(t).sum(axis=0)
                    for o, t in zip(tele_as_tuple(obs.tele), tele)))
            fs = tuple(np.array(x).reshape((K * ns,)
                                           + np.asarray(x).shape[2:])
                       for x in fs)
            ss = tuple(np.asarray(x) for x in ss)
        return state_from_tuple(fs), sched_state_from_tuple(ss)

    def _build_serve_sharded(self, sp: SchedParams, n_ticks: int,
                             dispatch_every: int, op, use_mesh: bool):
        from jax.sharding import PartitionSpec as P

        from repro.fleet import sched as S
        from repro.sharding.context import (FLEET_AXIS, make_fleet_mesh,
                                            shard_map_compat)
        p = self.p
        K = sp.shards
        ns = p.n // K
        quant = self.kernel != "xla"
        obs_cs = (self._power_cumsum()
                  if op is not None and sp.forecast else None)
        if op is not None:
            from repro.obs.state import init_tele, tele_as_tuple
            tele_tmpl = [(x.shape, x.dtype)
                         for x in tele_as_tuple(init_tele(op))]

        def per_shard(sh, i0):
            # the shard view: same backend methods, per-worker constants
            # swapped for this shard's contiguous rows (phase=0 rows are
            # synthesized when global phase is None: (i+0)%T == i%T)
            view = copy.copy(self)
            view.p = dataclasses.replace(p, n=ns)
            view.trace_index = sh["ti"]
            view.phase = sh["ph"]
            view.C = sh["C"]
            view.v_max = sh["v_max"]
            view.AP = sh["AP"]
            if quant:
                view._qp = dataclasses.replace(self._qp, **sh["qp"])
            sps = S.shard_sched_params(sp, per_worker=sh["sp"])

            rebalance = None
            if sp.rebalance_every:
                fwd = [(s, (s + 1) % K) for s in range(K)]
                bwd = [((s + 1) % K, s) for s in range(K)]

                def rebalance(ss, budget_plan):
                    # forecast-weighted surplus exchange around the
                    # shard ring (docs/sharded_fleet.md): all-integer,
                    # so the NumPy twin (rebalance_host) is bit-equal
                    cap = S.rebalance_capacity(budget_plan, jnp)
                    backlog = jnp.sum(ss.q_len)
                    b_tot = lax.psum(backlog, FLEET_AXIS)
                    c_tot = lax.psum(cap, FLEET_AXIS)
                    surplus, deficit = S.rebalance_targets(
                        backlog, cap, b_tot, c_tot, jnp)
                    give = jnp.minimum(
                        surplus, lax.ppermute(deficit, FLEET_AXIS, bwd))
                    move = S.rebalance_moves(sps, ss.q_len, give, jnp)
                    ss, bt, br = S.queue_pop_tail(sps, ss, move, jnp)
                    got = [lax.ppermute(x, FLEET_AXIS, fwd)
                           for x in (move, bt, br)]
                    return S.queue_push_tail(sps, ss, *got, xp=jnp)

            body = self._serve_body(view, sps, dispatch_every, op=op,
                                    obs_cs=obs_cs, rebalance=rebalance)
            fs, ss, arr = sh["fs"], sh["ss"], sh["arr"]
            idx = jnp.arange(n_ticks, dtype=jnp.int64)
            if op is None:
                (fs, ss), _ = lax.scan(body, (fs, S.SS(*ss)),
                                       (i0 + idx, arr))
                return fs, tuple(ss)
            tele = tuple(jnp.zeros(s, d) for s, d in tele_tmpl)
            # global obs index j = i0 + local, matching the host twin
            ((fs, ss), (tele, _)), _ = lax.scan(
                body, ((fs, S.SS(*ss)), (tele, None)),
                (i0 + idx, i0 + idx, arr))
            return fs, tuple(ss), tele

        if use_mesh:
            mesh = make_fleet_mesh(K)

            def shard_fn(sh, i0):
                out = per_shard(jax.tree.map(lambda x: x[0], sh), i0)
                return jax.tree.map(lambda x: x[None], out)

            mapped = shard_map_compat(shard_fn, mesh=mesh,
                                      in_specs=(P(FLEET_AXIS), P()),
                                      out_specs=P(FLEET_AXIS))
        else:
            mapped = jax.vmap(per_shard, in_axes=(0, None),
                              axis_name=FLEET_AXIS)
        return jax.jit(mapped)

    def _usable(self, v):
        return capacitor_usable_energy(v, capacitance_f=self.C,
                                       v_off=self.p.v_off, xp=jnp)

    def _draw(self, v, amount):
        return capacitor_draw(v, amount, capacitance_f=self.C,
                              v_off=self.p.v_off, xp=jnp)

    def _harvest(self, v, pw):
        p = self.p
        if self.use_pallas:
            from repro.kernels.fleet_step import harvest_step
            return harvest_step(v, pw, self.C, self.v_max, eff=p.eff,
                                dt=p.dt, interpret=self.interpret)
        return capacitor_harvest(v, pw, p.dt, capacitance_f=self.C,
                                 booster_eff=p.eff, v_max=self.v_max,
                                 xp=jnp)

    def _rec(self, ev, mask, code, t, ticket, units):
        """Record events for ``mask`` lanes into the fixed-capacity log
        (first event per worker per macro-step wins; see module docstring
        for why a second cannot occur)."""
        evc, evt, evtk, evu = ev
        new = mask & (evc == EV_NONE)
        return (jnp.where(new, code, evc), jnp.where(new, t, evt),
                jnp.where(new, ticket, evtk), jnp.where(new, units, evu))

    def _tick_q(self, st, ev, i):
        """Quantized tick as pure XLA: the ``kernel="q32"`` path — the
        exact xp-generic integer expressions of ``repro.fleet.qtick``
        traced with ``xp=jnp`` (the reference the Pallas megakernel is
        pinned against, and the measured CPU speedup over float64)."""
        from repro.fleet import qtick as Q
        qh = Q.harvest_row(self.p, self._qp, self.trace_index,
                           self.phase, i, jnp)
        return Q.tick_q(self.p, self._qp, st, ev, qh, i, jnp,
                        lax.while_loop)

    def _tick_pallas(self, st, ev, i):
        """Quantized tick as one fused Pallas pass per tick
        (``repro.kernels.serve_tick``): compiled on TPU, interpret-mode
        on CPU. The kernel emits a fresh event log; it is merged into
        the carried log first-event-wins so macro-step runs keep the
        one-event-per-worker invariant."""
        from repro.fleet import qtick as Q
        from repro.kernels import serve_tick as K
        p = self.p
        s = _S(*st)
        qh = Q.harvest_row(p, self._qp, self.trace_index, self.phase, i,
                           jnp)
        rw = {f: getattr(s, f) for f in K.RW_FIELDS}
        ro = {f: getattr(s, f) for f in K.RO_FIELDS}
        consts = dict(e_on=self._qp.E_ON, e_off=self._qp.E_OFF,
                      e_max=self._qp.E_MAX, estep=self._qp.ESTEP)
        rw_out, evk, _led = K.serve_tick(
            rw, ro, consts, self._k_tables, qh.astype(jnp.int32),
            i.astype(jnp.int32) if hasattr(i, "astype")
            else jnp.int32(i),
            u_max=int(p.UC.shape[1]), interpret=self.interpret)
        evc0 = ev[0]
        new = (evk[0] != EV_NONE) & (evc0 == EV_NONE)
        ev = tuple(jnp.where(new, a, b) for a, b in zip(evk, ev))
        return tuple(s._replace(**rw_out)), ev

    def _tick(self, st, ev, i):
        p = self.p
        s = _S(*st)
        dt = p.dt
        t = i * dt

        # 1. harvest (mirrors Capacitor.harvest)
        col = (i % p.T) if self.phase is None else (i + self.phase) % p.T
        pw = self.power[self.trace_index, col]
        e_harvest = s.e_harvest + p.eff * pw * dt
        v = self._harvest(s.v, pw)

        # 2. turn on at v_on
        waking = ~s.on & (v >= p.v_on)
        on = s.on | waking
        cycles = s.cycles + waking
        working = on & s.has_work
        idle = on & ~s.has_work
        s = s._replace(v=v, on=on, cycles=cycles, e_harvest=e_harvest)

        # 2b. persistence plane: pay the FRAM restore read before the
        # worker may progress again (the restore consumes its tick)
        if p.persist != "none":
            s, working = self._restore(s, working)

        # 3. acquisition
        if p.mode == "local":
            s = self._acquire_local(s, idle, t)
        else:
            s, ev = self._acquire_dispatch(s, idle, t, ev)

        # 4. progress in-flight work by one dt of active execution
        s, ev, emit_now = self._progress(s, working, t, ev)

        # 5. emission (BLE packet / host transfer)
        finish = (working & s.has_work & s.on
                  & ((s.w_units_done >= s.w_target) | emit_now))
        s, ev = self._emit(s, finish, t, ev)
        return tuple(s), ev

    def _acquire_local(self, s, idle, t):
        p = self.p
        due = idle & (t >= s.next_sample_t)
        delta = t - s.next_sample_t
        k = jnp.floor_divide(delta, p.P)
        sample_counter = s.sample_counter + jnp.where(
            due, k.astype(jnp.int64) + 1, 0)
        next_sample_t = s.next_sample_t + jnp.where(
            due, p.P * (k + 1.0), 0.0)
        # decide BEFORE spending anything (SMART skips the whole round)
        us = self._usable(s.v)
        from repro.core.policies import SKIP
        init, refine = p.policy.decide_batch(us, p.tables[0], p.acc,
                                             xp=jnp)
        skip = due & (init == SKIP)
        skipped = s.skipped + skip
        go = due & ~(init == SKIP)
        fixed = p.FIX[0]
        v2, ok = self._draw(s.v, jnp.minimum(fixed, us))
        v = jnp.where(go, v2, s.v)
        on = s.on & ~(go & ~ok)
        succ = go & ok
        return s._replace(
            v=v, on=on, skipped=skipped, sample_counter=sample_counter,
            next_sample_t=next_sample_t,
            e_work=s.e_work + jnp.where(succ, fixed, 0.0),
            acquired=s.acquired + succ,
            has_work=s.has_work | succ,
            w_ticket=jnp.where(succ, sample_counter - 1, s.w_ticket),
            w_t_acq=jnp.where(succ, t, s.w_t_acq),
            w_cycle_acq=jnp.where(succ, s.cycles, s.w_cycle_acq),
            w_units_done=jnp.where(succ, 0, s.w_units_done),
            w_left=jnp.where(succ, 0.0, s.w_left),
            w_target=jnp.where(succ, jnp.where(refine, p.NU[0], init),
                               s.w_target),
            w_tile=jnp.where(succ, 0, s.w_tile),
            w_wl=jnp.where(succ, 0, s.w_wl),
            w_batch=jnp.where(succ, 1, s.w_batch))

    def _restore(self, s, working):
        """Persistence-plane restore (persist != "none"): pay the FRAM
        read that reloads the progress image (ckpt) or log header
        (undolog); the restore consumes the worker's tick. Mirrors
        ``backend_numpy._restore`` expression for expression."""
        p = self.p
        rest = working & s.need_restore
        rj = self.REST_J[s.w_wl]
        v2, okr = self._draw(s.v, rj)
        v = jnp.where(rest, v2, s.v)
        okrest = rest & okr
        failr = rest & ~okr
        wud = s.w_units_done
        if p.persist == "ckpt":
            # Mementos semantics: rewind to the checkpointed counter
            wud = jnp.where(okrest, s.ck_units, wud)
        s = s._replace(
            v=v, on=s.on & ~failr,
            need_restore=s.need_restore & ~okrest,
            restores=s.restores + okrest,
            e_persist=s.e_persist + jnp.where(okrest, rj, 0.0),
            w_units_done=wud,
            w_left=jnp.where(okrest, 0.0, s.w_left))
        return s, working & ~rest

    def _acquire_dispatch(self, s, idle, t, ev):
        p = self.p
        due = idle & s.p_pending
        us = self._usable(s.v)
        fixed = self.FIX[s.p_wl]
        v2, ok = self._draw(s.v, jnp.minimum(fixed, us))
        v = jnp.where(due, v2, s.v)
        fail = due & ~ok
        succ = due & ok
        on = s.on & ~fail
        if p.persist == "none":
            p_pending = s.p_pending & ~due
            ev = self._rec(ev, fail, EV_LOST, t, s.p_ticket, 0)
        else:
            # exact disciplines never drop an accepted request: a failed
            # acquisition keeps the assignment pending across recharge
            p_pending = s.p_pending & ~succ
        s = s._replace(
            v=v, on=on, p_pending=p_pending,
            e_work=s.e_work + jnp.where(succ, fixed, 0.0),
            acquired=s.acquired + succ,
            has_work=s.has_work | succ,
            w_ticket=jnp.where(succ, s.p_ticket, s.w_ticket),
            w_t_acq=jnp.where(succ, t, s.w_t_acq),
            w_cycle_acq=jnp.where(succ, s.cycles, s.w_cycle_acq),
            w_units_done=jnp.where(succ, 0, s.w_units_done),
            w_left=jnp.where(succ, 0.0, s.w_left),
            w_tile=jnp.where(succ, s.p_units, s.w_tile),
            w_batch=jnp.where(succ, s.p_batch, s.w_batch),
            w_target=jnp.where(succ, s.p_units * s.p_batch, s.w_target),
            w_wl=jnp.where(succ, s.p_wl, s.w_wl))
        if p.persist != "none":
            # fresh request: clear stale persistence from a predecessor
            s = s._replace(need_restore=s.need_restore & ~succ,
                           ck_units=jnp.where(succ, 0, s.ck_units))
        return s, ev

    def _progress(self, s, working, t, ev):
        p = self.p
        dispatch = p.mode == "dispatch"
        u_max = p.UC.shape[1]
        e_step = jnp.where(working, self.AP * p.dt, 0.0)
        run = working & (s.w_units_done < s.w_target)
        emit_now = jnp.zeros(p.n, dtype=bool)
        ckpt_w = self.CKPT_J[s.w_wl]
        commit_w = self.COMMIT_J[s.w_wl]
        carry = (s.v, s.on, s.has_work, s.e_work, s.w_left, s.w_units_done,
                 e_step, run, emit_now, ev,
                 s.need_restore, s.ck_units, s.e_persist, s.persists)

        def cond(c):
            return jnp.any(c[7])

        def body(c):
            (v, on, has_work, e_work, w_left, w_units_done, e_step, run,
             emit_now, ev, need_restore, ck_units, e_persist,
             persists) = c
            # unit boundary: start the next unit only if unit + reserve
            # are affordable now. Approximate: reserve = the BLE emit
            # packet and "cant" emits the partial result. Exact: the
            # reserve also covers the checkpoint image / unit commit,
            # and "cant" is a forced power-down — the request persists.
            starting = run & (w_left <= 0)
            gidx = jnp.where(s.w_tile > 0,
                             w_units_done % jnp.maximum(s.w_tile, 1),
                             w_units_done)
            nc = self.UC[s.w_wl, jnp.clip(gidx, 0, u_max - 1)]
            us = self._usable(v)
            if p.persist == "none":
                cant = starting & (us < nc + self.EMITC[s.w_wl])
                emit_now = emit_now | cant
            else:
                rsv = ckpt_w if p.persist == "ckpt" else commit_w
                cant = starting & (us < nc + rsv + self.EMITC[s.w_wl])
                if p.persist == "ckpt":
                    # voltage trigger fired: serialize dirty progress
                    # to FRAM before dying (funded by the previous
                    # boundary's reserve)
                    dirty = cant & (w_units_done != ck_units)
                    v2, okc = self._draw(v, ckpt_w)
                    v = jnp.where(dirty, v2, v)
                    wrote = dirty & okc
                    ck_units = jnp.where(wrote, w_units_done, ck_units)
                    persists = persists + wrote
                    e_persist = e_persist + jnp.where(wrote, ckpt_w, 0.0)
                on = on & ~cant
                need_restore = need_restore | cant
            run = run & ~cant
            w_left = jnp.where(starting & ~cant, nc, w_left)
            take = jnp.minimum(e_step, w_left)
            v2, ok = self._draw(v, take)
            v = jnp.where(run, v2, v)
            fail = run & ~ok
            on = on & ~fail
            if p.persist == "none":
                # power failure mid-work: volatile by design; work lost
                has_work = has_work & ~fail
                if dispatch:
                    ev = self._rec(ev, fail, EV_LOST, t, s.w_ticket, 0)
            else:
                # the persisted request survives; restore re-runs it
                need_restore = need_restore | fail
            run = run & ok
            e_work = e_work + jnp.where(run, take, 0.0)
            w_left = jnp.where(run, w_left - take, w_left)
            e_step = jnp.where(run, e_step - take, e_step)
            fin = run & (w_left <= 1e-18)
            if p.persist == "undolog":
                # Alpaca task commit: the completed unit's undo-buffer
                # write makes w_units_done durable (funded by the
                # boundary reserve)
                v2, okc = self._draw(v, commit_w)
                v = jnp.where(fin, v2, v)
                halted = fin & ~okc
                on = on & ~halted
                need_restore = need_restore | halted
                run = run & ~halted
                fin = fin & okc
                persists = persists + fin
                e_persist = e_persist + jnp.where(fin, commit_w, 0.0)
            w_units_done = w_units_done + fin
            w_left = jnp.where(fin, 0.0, w_left)
            run = run & (e_step > 0) & (w_units_done < s.w_target)
            return (v, on, has_work, e_work, w_left, w_units_done, e_step,
                    run, emit_now, ev, need_restore, ck_units, e_persist,
                    persists)

        (v, on, has_work, e_work, w_left, w_units_done, _, _, emit_now,
         ev, need_restore, ck_units, e_persist, persists
         ) = lax.while_loop(cond, body, carry)
        s = s._replace(v=v, on=on, has_work=has_work, e_work=e_work,
                       w_left=w_left, w_units_done=w_units_done,
                       need_restore=need_restore, ck_units=ck_units,
                       e_persist=e_persist, persists=persists)
        return s, ev, emit_now

    def _emit(self, s, finish, t, ev):
        p = self.p
        ec = self.EMITC[s.w_wl]
        v2, ok = self._draw(s.v, ec)
        v = jnp.where(finish, v2, s.v)
        efail = finish & ~ok
        esucc = finish & ok
        on = s.on & ~efail
        if p.persist == "none":
            has_work = s.has_work & ~finish  # volatile: failed emission
            # loses the work
        else:
            # persisted work retries the emission after the next restore
            has_work = s.has_work & ~esucc
            s = s._replace(need_restore=s.need_restore | efail)
        if p.mode == "dispatch":
            if p.persist == "none":
                ev = self._rec(ev, efail, EV_LOST, t, s.w_ticket, 0)
            ev = self._rec(ev, esucc, EV_EMIT, t, s.w_ticket,
                           s.w_units_done)
        emit_acc_sum = s.emit_acc_sum
        if p.mode == "local":
            emit_acc_sum = emit_acc_sum + jnp.where(
                esucc,
                self.ACC[jnp.clip(s.w_units_done, 0, int(p.NU[0]))], 0.0)
        return s._replace(
            v=v, on=on, has_work=has_work,
            e_work=s.e_work + jnp.where(esucc, ec, 0.0),
            emit_count=s.emit_count + esucc,
            emit_units_sum=s.emit_units_sum + jnp.where(
                esucc, s.w_units_done, 0),
            emit_acc_sum=emit_acc_sum), ev
